//! Storage node: one OS thread per node, executing coordinator commands.
//!
//! A node owns a block store and its two NIC limiters. Commands arrive on
//! a clock-channel queue; data-plane commands run on worker threads drawn
//! from a bounded per-node pool (cap set by `ClusterSpec::max_workers`) so
//! a node can serve several concurrent roles (e.g. upload a source block
//! while acting as a pipeline stage for another object — exactly the
//! contention the multi-object experiments of Fig. 4b/5b create) without
//! unbounded thread spawning. Commands beyond the cap queue FIFO and start
//! as workers free up. NIC token buckets keep the bandwidth accounting
//! honest regardless of the worker count.
//!
//! The node loop and every worker are clock *participants*
//! ([`crate::clock::BusyToken`]): under a `SimClock` their runnable/idle
//! transitions drive virtual-time advancement, and all queue waits happen
//! in virtual time.
//!
//! The cap is a *soft* bound: streaming commands block while waiting for
//! peer data, so running commands can depend (transitively, across nodes)
//! on commands still sitting in a queue — a hard cap could deadlock such a
//! workload. Whenever a command has been queued for
//! [`QUEUE_STALL_OVERFLOW`] without any worker finishing, the node runs
//! one queued command beyond the cap, guaranteeing progress. Two guards
//! keep that overflow from quietly unbounding the pool when workers are
//! merely slow (long transfers) rather than deadlocked: consecutive stall
//! spawns back off exponentially (doubling up to 20× the base timeout),
//! and completions reclaim overflow slots before any queued command is
//! refilled. In the steady state (the paper's 16-object batch puts ≤ 16
//! commands on each node, default cap 32) the overflow never triggers.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::link::{Frame, Payload, Rx, Tx};
use super::nic::RateLimiter;
use super::NodeId;
use crate::backend::{BackendHandle, Width};
use crate::clock::{self, blocked, BusyToken, Clock, ClockHandle, RecvTimeoutError, Tick};
use crate::resources::{CpuMeter, GfWork};
use crate::storage::{BlockKey, BlockStore};

/// Default per-node worker-thread cap (see the module docs for sizing).
pub const DEFAULT_MAX_WORKERS: usize = 32;

/// What a completed data-plane command reports alongside success: the
/// virtual compute time it charged to the node's [`CpuMeter`]. The plan
/// executor subtracts this from a step's end-to-end span to split
/// compute from transfer occupancy.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct StepStats {
    /// Total compute time charged (ZERO under the `ZeroCost` model).
    pub compute: Tick,
    /// Tick at which the command finished, stamped by the worker (or task)
    /// that ran it, immediately before the completion signal. The plan
    /// executor closes each step's span at this tick, so the recorded
    /// stage times do not depend on when the result is collected.
    pub finished_at: Tick,
}

/// Completion payload of every data-plane command.
pub type StepResult = anyhow::Result<StepStats>;

/// How long (on the cluster clock) a queued data-plane command may wait
/// with no worker finishing before the cap is exceeded by one to guarantee
/// progress (anti-deadlock overflow — see the module docs).
pub const QUEUE_STALL_OVERFLOW: std::time::Duration = std::time::Duration::from_millis(100);

/// Commands a storage node executes.
pub enum Command {
    /// Store a block directly (control plane, unmetered ingest).
    Put {
        /// Block key.
        key: BlockKey,
        /// Payload.
        data: Vec<u8>,
        /// Completion signal.
        done: clock::Sender<anyhow::Result<()>>,
    },
    /// Read a block directly (control plane, unmetered; used by the
    /// coordinator for verification/decode assembly).
    Peek {
        /// Block key.
        key: BlockKey,
        /// Reply channel.
        reply: clock::Sender<Option<Arc<Vec<u8>>>>,
    },
    /// Delete a block (replica reclaim after migration).
    Delete {
        /// Block key.
        key: BlockKey,
        /// Completion signal with "existed" flag.
        done: clock::Sender<bool>,
    },
    /// Stream a stored block out through `tx` in `buf_bytes` frames
    /// (metered by both NICs — the data plane read path).
    Upload {
        /// Block to stream.
        key: BlockKey,
        /// Outgoing link.
        tx: Tx,
        /// Frame size.
        buf_bytes: usize,
        /// Completion signal.
        done: clock::Sender<StepResult>,
    },
    /// Receive a streamed block from `rx` and store it under `key`
    /// (the data plane write path; parity distribution in classical coding).
    Receive {
        /// Destination key.
        key: BlockKey,
        /// Incoming link.
        rx: Rx,
        /// Expected stream size in bytes (pre-sizes the receive buffer;
        /// 0 = unknown, the buffer grows as frames arrive).
        expect_bytes: usize,
        /// Completion signal.
        done: clock::Sender<StepResult>,
    },
    /// Act as one stage of a RapidRAID encoding pipeline: for every
    /// incoming buffer fold the local blocks with ψ/ξ, forward `x_out`
    /// downstream and append `c` locally (paper eqs. (3)/(4), streamed).
    PipelineStage {
        /// GF width (RR8/RR16).
        width: Width,
        /// Local source blocks to fold (1 or 2).
        locals: Vec<BlockKey>,
        /// Forward coefficients ψ (one per local).
        psi: Vec<u32>,
        /// Codeword coefficients ξ (one per local).
        xi: Vec<u32>,
        /// Upstream link (None for the pipeline head, which synthesizes
        /// zero buffers).
        prev: Option<Rx>,
        /// Downstream links: one per child subtree. A chain stage has one,
        /// a tree interior stage several (every child receives a shared
        /// view of the same `x_out` frame — the modeled duplication is
        /// charged as XOR work, no physical copy is made), a tail none.
        next: Vec<Tx>,
        /// Where to store the locally generated block: `Some` stores the
        /// c output (archival: codeword block c_i; pipelined-decode tail:
        /// the recovered source block), `None` discards it (pipelined-
        /// decode intermediate stages only relay the running combination).
        out_key: Option<BlockKey>,
        /// Frame size (must equal upstream frame size).
        buf_bytes: usize,
        /// GF compute backend.
        backend: BackendHandle,
        /// Completion signal.
        done: clock::Sender<StepResult>,
    },
    /// Act as the single coding node of a classical erasure encoding:
    /// stream k source blocks from `sources`, fold each buffer into m
    /// parity accumulators as it arrives (streamlined, Section III), and
    /// stream finished parity buffers out (or keep them locally) as soon as
    /// each row of k source buffers has been folded.
    ClassicalEncode {
        /// GF width.
        width: Width,
        /// Incoming source streams, in generator-column order. A `Local`
        /// entry reads the block from this node's store (data locality).
        sources: Vec<SourceStream>,
        /// Parity coefficient rows: `parity_rows[i][j]` multiplies source j
        /// into parity i (the Cauchy G′ of the (n,k) code — or any full
        /// generator when the plan lowers a non-systematic code atomically).
        parity_rows: Vec<Vec<u32>>,
        /// Per-parity destination: stream out, or store locally (locality).
        dests: Vec<ParityDest>,
        /// Frame size.
        buf_bytes: usize,
        /// Block size (all sources equal).
        block_bytes: usize,
        /// GF compute backend.
        backend: BackendHandle,
        /// Completion signal.
        done: clock::Sender<StepResult>,
    },
    /// Stop the node thread (workers already running keep finishing; any
    /// still-queued data-plane commands are started before the loop exits).
    Shutdown,
}

/// One classical-encode input: either a network stream or a local block.
pub enum SourceStream {
    /// Remote source arriving on this link.
    Remote(Rx),
    /// Local replica (data locality — no network transfer).
    Local(BlockKey),
}

/// One classical-encode output: stream it out or keep it on this node.
pub enum ParityDest {
    /// Stream this output over the link (remote destination).
    Stream(Tx),
    /// Accumulate locally and store under the key (data locality).
    Store(BlockKey),
}

/// Internal node-loop message: an external command or a worker-slot
/// release from a finished data-plane worker. `pub(crate)` so the
/// multiplexed runtime's node task speaks the same protocol.
pub(crate) enum Msg {
    Cmd(Command),
    WorkerDone,
}

/// Handle to a running storage node.
pub struct NodeHandle {
    /// Node id within the cluster.
    pub id: NodeId,
    /// Command queue.
    cmd: clock::Sender<Msg>,
    /// The node's block store (shared; coordinator uses it read-only in
    /// tests/verification).
    pub store: BlockStore,
    /// Upload NIC.
    pub up: Arc<RateLimiter>,
    /// Download NIC.
    pub down: Arc<RateLimiter>,
    /// CPU meter every data-plane worker of this node charges.
    pub cpu: Arc<CpuMeter>,
    clock: ClockHandle,
    thread: Option<JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
    failed: Arc<AtomicBool>,
}

impl NodeHandle {
    /// Spawn a node thread with the given NIC limiters and CPU meter
    /// (which must share a clock) and worker cap (`max_workers` is
    /// clamped to ≥ 1).
    pub fn spawn(
        id: NodeId,
        up: Arc<RateLimiter>,
        down: Arc<RateLimiter>,
        cpu: Arc<CpuMeter>,
        max_workers: usize,
    ) -> Self {
        let clock = up.clock().clone();
        let store = BlockStore::new();
        let (tx, rx) = clock::channel::<Msg>(&clock);
        let store2 = store.clone();
        let cpu2 = cpu.clone();
        let inflight = Arc::new(AtomicUsize::new(0));
        let inflight2 = inflight.clone();
        let failed = Arc::new(AtomicBool::new(false));
        let failed2 = failed.clone();
        let loopback = tx.clone();
        let clock2 = clock.clone();
        // Token created before the spawn: the node counts as busy from the
        // instant it exists, so virtual time can't slip during startup.
        let token = BusyToken::new(&clock);
        let thread = std::thread::Builder::new()
            .name(format!("node-{id}"))
            .spawn(move || {
                let _busy = token.bind();
                node_loop(
                    id,
                    clock2,
                    rx,
                    loopback,
                    store2,
                    cpu2,
                    inflight2,
                    failed2,
                    max_workers,
                )
            })
            .expect("spawn node thread");
        Self {
            id,
            cmd: tx,
            store,
            up,
            down,
            cpu,
            clock,
            thread: Some(thread),
            inflight,
            failed,
        }
    }

    /// Build a node WITHOUT its own OS thread: the returned [`NodeCore`]
    /// holds the command-queue receiver and loop state seeds, and the
    /// multiplexed runtime drives the node loop as a cooperatively
    /// scheduled task on its driver. The handle is indistinguishable from
    /// a [`NodeHandle::spawn`]ed one to every caller.
    pub(crate) fn multiplexed(
        id: NodeId,
        up: Arc<RateLimiter>,
        down: Arc<RateLimiter>,
        cpu: Arc<CpuMeter>,
        max_workers: usize,
    ) -> (Self, NodeCore) {
        let clock = up.clock().clone();
        let store = BlockStore::new();
        let (tx, rx) = clock::channel::<Msg>(&clock);
        let inflight = Arc::new(AtomicUsize::new(0));
        let failed = Arc::new(AtomicBool::new(false));
        let core = NodeCore {
            id,
            rx,
            loopback: tx.clone(),
            store: store.clone(),
            cpu: cpu.clone(),
            inflight: inflight.clone(),
            failed: failed.clone(),
            max_workers: max_workers.max(1),
        };
        (
            Self {
                id,
                cmd: tx,
                store,
                up,
                down,
                cpu,
                clock,
                thread: None,
                inflight,
                failed,
            },
            core,
        )
    }

    /// The clock this node runs on.
    pub fn clock(&self) -> &ClockHandle {
        &self.clock
    }

    /// Enqueue a command. Errors fast when the node has crashed
    /// ([`NodeHandle::fail`]) — nothing is enqueued.
    pub fn send(&self, cmd: Command) -> anyhow::Result<()> {
        anyhow::ensure!(!self.is_failed(), "node {} has failed", self.id);
        self.cmd
            .send(Msg::Cmd(cmd))
            .map_err(|_| anyhow::anyhow!("node {} is down", self.id))
    }

    /// Crash-stop this node: subsequent commands error fast, stored blocks
    /// are lost (the simulated disk dies with the node), queued data-plane
    /// commands are rejected, and guarded links touching the node break.
    /// The node thread itself keeps running so [`NodeHandle::revive`] can
    /// bring the node back (empty) without respawning.
    pub fn fail(&self) {
        self.failed.store(true, Ordering::SeqCst);
        self.store.clear();
    }

    /// Bring a crashed node back as an empty newcomer: commands are
    /// accepted again; the pre-crash blocks stay lost (repair must
    /// regenerate them).
    pub fn revive(&self) {
        self.failed.store(false, Ordering::SeqCst);
    }

    /// Whether the node is currently crashed.
    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::SeqCst)
    }

    /// Shared failure flag (the cluster attaches it to link guards).
    pub fn failure_flag(&self) -> Arc<AtomicBool> {
        self.failed.clone()
    }

    /// Synchronous Put convenience.
    pub fn put(&self, key: BlockKey, data: Vec<u8>) -> anyhow::Result<()> {
        let (done, wait) = clock::channel(&self.clock);
        self.send(Command::Put { key, data, done })?;
        wait.recv()?
    }

    /// Synchronous Peek convenience.
    pub fn peek(&self, key: BlockKey) -> anyhow::Result<Option<Arc<Vec<u8>>>> {
        let (reply, wait) = clock::channel(&self.clock);
        self.send(Command::Peek { key, reply })?;
        Ok(wait.recv()?)
    }

    /// Synchronous Delete convenience.
    pub fn delete(&self, key: BlockKey) -> anyhow::Result<bool> {
        let (done, wait) = clock::channel(&self.clock);
        self.send(Command::Delete { key, done })?;
        Ok(wait.recv()?)
    }

    /// Number of data-plane commands currently executing or queued —
    /// the load signal congestion-aware chain policies rank nodes by.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }
}

impl Drop for NodeHandle {
    fn drop(&mut self) {
        let _ = self.cmd.send(Msg::Cmd(Command::Shutdown));
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Everything the multiplexed runtime needs to run one node's command
/// loop as a task: the receive side of the queue [`NodeHandle`] sends on,
/// plus the shared state the threaded `node_loop` closes over.
pub(crate) struct NodeCore {
    pub(crate) id: NodeId,
    pub(crate) rx: clock::Receiver<Msg>,
    pub(crate) loopback: clock::Sender<Msg>,
    pub(crate) store: BlockStore,
    pub(crate) cpu: Arc<CpuMeter>,
    pub(crate) inflight: Arc<AtomicUsize>,
    pub(crate) failed: Arc<AtomicBool>,
    pub(crate) max_workers: usize,
}

/// Answer a command's completion channel with a crash error (the node is
/// failed: nothing runs, but every caller must still get a reply).
pub(crate) fn reject(id: NodeId, cmd: Command) {
    let crash = || anyhow::anyhow!("node {id} has failed");
    match cmd {
        Command::Put { done, .. } => {
            let _ = done.send(Err(crash()));
        }
        Command::Peek { reply, .. } => {
            let _ = reply.send(None);
        }
        Command::Delete { done, .. } => {
            let _ = done.send(false);
        }
        Command::Upload { done, .. }
        | Command::Receive { done, .. }
        | Command::PipelineStage { done, .. }
        | Command::ClassicalEncode { done, .. } => {
            let _ = done.send(Err(crash()));
        }
        Command::Shutdown => {}
    }
}

#[allow(clippy::too_many_arguments)]
fn node_loop(
    id: NodeId,
    clock: ClockHandle,
    rx: clock::Receiver<Msg>,
    loopback: clock::Sender<Msg>,
    store: BlockStore,
    cpu: Arc<CpuMeter>,
    inflight: Arc<AtomicUsize>,
    failed: Arc<AtomicBool>,
    max_workers: usize,
) {
    let max_workers = max_workers.max(1);
    let mut workers: Vec<JoinHandle<()>> = Vec::new();
    let mut pending: VecDeque<Command> = VecDeque::new();
    let mut active = 0usize;
    let spawn_worker = |cmd: Command, workers: &mut Vec<JoinHandle<()>>| {
        let store = store.clone();
        let cpu = cpu.clone();
        let inflight = inflight.clone();
        let loopback = loopback.clone();
        let failed = failed.clone();
        // Parent-created token: no gap between spawn and accounting.
        let token = BusyToken::new(&clock);
        workers.push(std::thread::spawn(move || {
            let _busy = token.bind();
            run_dataplane(cmd, store, &cpu, &failed);
            inflight.fetch_sub(1, Ordering::Relaxed);
            // Release the worker slot; the node loop may have shut down
            // already, in which case nobody is waiting for the slot.
            let _ = loopback.send(Msg::WorkerDone);
        }));
    };
    // Stall-overflow state: the deadline is anchored to the last PROGRESS
    // event (a worker finishing), not to message arrival — otherwise
    // steady control-plane traffic (peeks, new commands) would push the
    // window forever and defeat the progress guarantee. Backoff doubles on
    // consecutive overflow spawns, resets when a worker finishes. All
    // deadlines live on the cluster clock: under a SimClock a stalled
    // queue becomes a discrete event at `now + stall`, so the overflow
    // fires after 100 *virtual* milliseconds without any wall-clock wait.
    let mut stall = QUEUE_STALL_OVERFLOW;
    let max_stall = QUEUE_STALL_OVERFLOW * 20;
    let mut stall_deadline: Option<Tick> = None;
    // The loop holds a loopback sender, so `recv` can only end via Shutdown.
    loop {
        // A crash rejects everything still queued (each queued data-plane
        // command was counted in `inflight` on arrival, so the load signal
        // stays balanced). Workers already running keep going; their link
        // guards break any stream touching this node.
        if failed.load(Ordering::SeqCst) {
            let flushed = !pending.is_empty();
            while let Some(cmd) = pending.pop_front() {
                inflight.fetch_sub(1, Ordering::Relaxed);
                reject(id, cmd);
            }
            if flushed {
                crate::trace_emit!(clock, id, crate::trace::EventKind::QueueDepth {
                    depth: active
                });
            }
            stall_deadline = None;
        }
        let msg = if pending.is_empty() {
            stall_deadline = None;
            match rx.recv() {
                Ok(m) => m,
                Err(_) => break,
            }
        } else {
            // With commands queued, wait bounded: if nothing completes by
            // the stall deadline, the running workers may be blocked on a
            // queued command (mutual streaming dependencies can cross
            // nodes) — run one beyond the cap to guarantee progress, then
            // back off so slow-but-progressing workloads erode the cap at
            // a decaying rate instead of linearly.
            let deadline = *stall_deadline.get_or_insert_with(|| clock.now() + stall);
            if clock.now() >= deadline {
                if let Some(cmd) = pending.pop_front() {
                    active += 1;
                    spawn_worker(cmd, &mut workers);
                }
                stall = (stall * 2).min(max_stall);
                stall_deadline = Some(clock.now() + stall);
                continue;
            }
            match rx.recv_deadline(deadline) {
                Ok(m) => m,
                // Deadline hit with no message: loop around to fire the
                // overflow branch above.
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        };
        match msg {
            // Commands that raced past the handle's failure check before
            // the crash land here: reply with the crash error, run nothing.
            Msg::Cmd(cmd)
                if failed.load(Ordering::SeqCst) && !matches!(cmd, Command::Shutdown) =>
            {
                reject(id, cmd);
            }
            Msg::WorkerDone => {
                stall = QUEUE_STALL_OVERFLOW;
                stall_deadline = None;
                active -= 1;
                // Reclaim overflow slots first: refill from the queue only
                // while under the cap, so stall overshoot drains away.
                if active < max_workers {
                    if let Some(cmd) = pending.pop_front() {
                        active += 1;
                        spawn_worker(cmd, &mut workers);
                    }
                }
                crate::trace_emit!(clock, id, crate::trace::EventKind::QueueDepth {
                    depth: active + pending.len()
                });
            }
            Msg::Cmd(Command::Shutdown) => {
                // Flush the queue (briefly exceeding the cap) so every
                // dispatched command still completes and signals `done`.
                while let Some(cmd) = pending.pop_front() {
                    spawn_worker(cmd, &mut workers);
                }
                break;
            }
            Msg::Cmd(Command::Put { key, data, done }) => {
                store.put(key, data);
                let _ = done.send(Ok(()));
            }
            Msg::Cmd(Command::Peek { key, reply }) => {
                let _ = reply.send(store.get(&key));
            }
            Msg::Cmd(Command::Delete { key, done }) => {
                let _ = done.send(store.delete(&key));
            }
            // Data-plane commands run on pooled worker threads so the node
            // can multiplex several roles; NIC limiters model the
            // bandwidth contention between them.
            Msg::Cmd(other) => {
                inflight.fetch_add(1, Ordering::Relaxed);
                if active < max_workers {
                    active += 1;
                    spawn_worker(other, &mut workers);
                } else {
                    pending.push_back(other);
                }
                crate::trace_emit!(clock, id, crate::trace::EventKind::QueueDepth {
                    depth: active + pending.len()
                });
            }
        }
        workers.retain(|w| !w.is_finished());
    }
    // Workers may still be sleeping on the clock: the join must not pin
    // virtual time or a SimClock could never wake them.
    for w in workers {
        let _ = blocked(&clock, move || w.join());
    }
}

/// Stamp a completed command's finish tick right before its result is
/// sent — shared by the threaded workers and the multiplexed tasks, so
/// `StepStats::finished_at` is runtime-independent.
pub(crate) fn stamp_finished(r: StepResult, clock: &ClockHandle) -> StepResult {
    r.map(|mut s| {
        s.finished_at = clock.now();
        s
    })
}

fn run_dataplane(cmd: Command, store: BlockStore, cpu: &CpuMeter, failed: &AtomicBool) {
    let clock = cpu.clock().clone();
    match cmd {
        Command::Upload {
            key,
            mut tx,
            buf_bytes,
            done,
        } => {
            let r = do_upload(&store, key, &mut tx, buf_bytes);
            let _ = done.send(stamp_finished(r, &clock));
        }
        Command::Receive {
            key,
            rx,
            expect_bytes,
            done,
        } => {
            let r = do_receive(&store, key, &rx, expect_bytes, cpu, failed);
            let _ = done.send(stamp_finished(r, &clock));
        }
        Command::PipelineStage {
            width,
            locals,
            psi,
            xi,
            prev,
            next,
            out_key,
            buf_bytes,
            backend,
            done,
        } => {
            let r = do_pipeline_stage(
                &store, width, &locals, &psi, &xi, prev, next, out_key, buf_bytes, &backend,
                cpu, failed,
            );
            let _ = done.send(stamp_finished(r, &clock));
        }
        Command::ClassicalEncode {
            width,
            sources,
            parity_rows,
            dests,
            buf_bytes,
            block_bytes,
            backend,
            done,
        } => {
            let r = do_classical_encode(
                &store,
                width,
                sources,
                &parity_rows,
                dests,
                buf_bytes,
                block_bytes,
                &backend,
                cpu,
                failed,
            );
            let _ = done.send(stamp_finished(r, &clock));
        }
        _ => unreachable!("control-plane command on data plane"),
    }
}

fn do_upload(store: &BlockStore, key: BlockKey, tx: &mut Tx, buf_bytes: usize) -> StepResult {
    let data = store
        .get(&key)
        .ok_or_else(|| anyhow::anyhow!("upload: missing block {key:?}"))?;
    // The stored Arc streams out as payload views — every frame is a
    // sub-range of the block's own allocation, no per-chunk copy.
    let payload = Payload::from_shared(data);
    let total = payload.len();
    let mut off = 0usize;
    while off < total {
        let end = (off + buf_bytes).min(total);
        tx.send_data(payload.slice(off, end))?;
        off = end;
    }
    tx.finish()?;
    // A stored-block read costs no GF work; the NICs price the transfer.
    Ok(StepStats::default())
}

/// Stream a block in. Frames append straight into one buffer pre-sized to
/// `expect_bytes` (the plan's block size), so the hot receive path does a
/// single allocation instead of `Vec` growth doubling over the stream.
fn do_receive(
    store: &BlockStore,
    key: BlockKey,
    rx: &Rx,
    expect_bytes: usize,
    cpu: &CpuMeter,
    failed: &AtomicBool,
) -> StepResult {
    let mut data = Vec::with_capacity(expect_bytes);
    rx.recv_into(&mut data)?;
    let bytes = data.len();
    // The store landing is the step's compute: charged before completion
    // so a Store step occupies virtual time on the node's core.
    let compute = cpu.charge(&GfWork::store(bytes));
    anyhow::ensure!(
        store.put_unless(key, data, failed),
        "receive aborted: node has failed"
    );
    crate::trace_emit!(
        cpu.clock(),
        cpu.node(),
        crate::trace::EventKind::StoreDone {
            object: key.object.0,
            index: key.index,
            bytes
        }
    );
    Ok(StepStats {
        compute,
        ..Default::default()
    })
}

/// One pipeline-stage frame fold, shared by BOTH dataplane runtimes (the
/// thread-per-node loop below and the multiplexed state machine in
/// `cluster::runtime`): price the frame's [`GfWork`] from coefficient
/// class + length BEFORE dispatching the fused backend step, so the two
/// runtimes charge byte-identical work no matter which SIMD kernel runs
/// underneath. Fan-out to extra children is *priced* as one XOR pass per
/// extra child (the modeled duplication cost) even though the forwarded
/// frames are refcounted views of one buffer.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fold_frame(
    backend: &BackendHandle,
    width: Width,
    x_in: &[u8],
    locals: &[&[u8]],
    psi: &[u32],
    xi: &[u32],
    fanout: usize,
) -> anyhow::Result<(Vec<u8>, Vec<u8>, GfWork)> {
    let mut work = GfWork::pipeline_step(psi, xi, x_in.len());
    if fanout > 1 {
        work += GfWork::xor((fanout - 1) * x_in.len());
    }
    let (x_out, c) = backend.pipeline_step(width, x_in, locals, psi, xi)?;
    Ok((x_out, c, work))
}

#[allow(clippy::too_many_arguments)]
fn do_pipeline_stage(
    store: &BlockStore,
    width: Width,
    locals: &[BlockKey],
    psi: &[u32],
    xi: &[u32],
    prev: Option<Rx>,
    mut next: Vec<Tx>,
    out_key: Option<BlockKey>,
    buf_bytes: usize,
    backend: &BackendHandle,
    cpu: &CpuMeter,
    failed: &AtomicBool,
) -> StepResult {
    let local_blocks: Vec<Arc<Vec<u8>>> = locals
        .iter()
        .map(|k| {
            store
                .get(k)
                .ok_or_else(|| anyhow::anyhow!("pipeline stage: missing local block {k:?}"))
        })
        .collect::<anyhow::Result<_>>()?;
    let block_bytes = local_blocks
        .first()
        .map(|b| b.len())
        .ok_or_else(|| anyhow::anyhow!("pipeline stage with no local blocks"))?;
    anyhow::ensure!(
        local_blocks.iter().all(|b| b.len() == block_bytes),
        "local blocks of unequal size"
    );

    let mut out = Vec::with_capacity(if out_key.is_some() { block_bytes } else { 0 });
    // Trace identity of this stage's stored output (None for relay-only
    // stages); copied out up front because `out_key` is consumed below.
    let (trace_obj, trace_idx) = match &out_key {
        Some(k) => (Some(k.object.0), Some(k.index)),
        None => (None, None),
    };
    let mut frame_no = 0usize;
    let mut compute = Tick::ZERO;
    let mut offset = 0usize;
    loop {
        // Obtain the incoming partial-combination buffer: from upstream, or
        // all-zero for the chain head.
        let x_in: Payload = match &prev {
            Some(rx) => match rx.recv() {
                Some(Frame::Data(d)) => d,
                Some(Frame::End) => break,
                None => anyhow::bail!("upstream link dropped mid-stream"),
            },
            None => {
                if offset >= block_bytes {
                    break;
                }
                Payload::new(vec![0u8; buf_bytes.min(block_bytes - offset)])
            }
        };
        let len = x_in.len();
        anyhow::ensure!(
            offset + len <= block_bytes,
            "incoming stream longer than local blocks"
        );
        let loc_slices: Vec<&[u8]> = local_blocks
            .iter()
            .map(|b| &b[offset..offset + len])
            .collect();
        crate::trace_emit!(
            cpu.clock(),
            cpu.node(),
            crate::trace::EventKind::FoldStart {
                object: trace_obj,
                index: trace_idx,
                frame: frame_no
            }
        );
        // Charge the frame's GF work BEFORE forwarding: the compute delay
        // paces the whole downstream pipeline, exactly like a slow CPU
        // would.
        let (x_out, c, work) = fold_frame(backend, width, &x_in, &loc_slices, psi, xi, next.len())?;
        compute += cpu.charge(&work);
        crate::trace_emit!(
            cpu.clock(),
            cpu.node(),
            crate::trace::EventKind::FoldEnd {
                object: trace_obj,
                index: trace_idx,
                frame: frame_no
            }
        );
        frame_no += 1;
        if out_key.is_some() {
            out.extend_from_slice(&c);
        }
        if let Some((last, rest)) = next.split_last_mut() {
            let frame = Payload::new(x_out);
            for tx in rest {
                tx.send_data(frame.clone())?;
            }
            last.send_data(frame)?;
        }
        offset += len;
    }
    for tx in &mut next {
        tx.finish()?;
    }
    anyhow::ensure!(offset == block_bytes, "stream/block length mismatch");
    if let Some(key) = out_key {
        let bytes = out.len();
        compute += cpu.charge(&GfWork::store(bytes));
        anyhow::ensure!(
            store.put_unless(key, out, failed),
            "pipeline stage aborted: node has failed"
        );
        crate::trace_emit!(
            cpu.clock(),
            cpu.node(),
            crate::trace::EventKind::StoreDone {
                object: key.object.0,
                index: key.index,
                bytes
            }
        );
    }
    Ok(StepStats {
        compute,
        ..Default::default()
    })
}

#[allow(clippy::too_many_arguments)]
fn do_classical_encode(
    store: &BlockStore,
    width: Width,
    sources: Vec<SourceStream>,
    parity_rows: &[Vec<u32>],
    mut dests: Vec<ParityDest>,
    buf_bytes: usize,
    block_bytes: usize,
    backend: &BackendHandle,
    cpu: &CpuMeter,
    failed: &AtomicBool,
) -> StepResult {
    let k = sources.len();
    let m = parity_rows.len();
    anyhow::ensure!(dests.len() == m, "dests/parity arity mismatch");
    anyhow::ensure!(
        parity_rows.iter().all(|r| r.len() == k),
        "parity row arity mismatch"
    );
    let local_blocks: Vec<Option<Arc<Vec<u8>>>> = sources
        .iter()
        .map(|s| match s {
            SourceStream::Local(key) => store.get(key).map(Some).ok_or_else(|| {
                anyhow::anyhow!("classical encode: missing local source {key:?}")
            }),
            SourceStream::Remote(_) => Ok(None),
        })
        .collect::<anyhow::Result<_>>()?;

    let mut local_acc: Vec<Vec<u8>> = dests
        .iter()
        .map(|d| match d {
            ParityDest::Store(_) => Vec::with_capacity(block_bytes),
            ParityDest::Stream(_) => Vec::new(),
        })
        .collect();
    let mut compute = Tick::ZERO;
    let mut offset = 0usize;
    // Streamlined loop (paper Section III): gather one "row" of k source
    // buffers (the k-th network buffer of every block), apply the parity
    // sub-matrix in ONE gemm (this is the AOT Pallas gf_gemm kernel on the
    // PJRT backend), and ship each parity buffer as soon as it exists.
    // Remote entries are the delivered frames as-is; local entries are
    // payload views into the stored block — no per-row copies either way.
    let mut row: Vec<Payload> = Vec::with_capacity(k);
    let mut frame_no = 0usize;
    while offset < block_bytes {
        let len = buf_bytes.min(block_bytes - offset);
        row.clear();
        for (j, src) in sources.iter().enumerate() {
            match src {
                SourceStream::Remote(rx) => {
                    let buf = match rx.recv() {
                        Some(Frame::Data(d)) => d,
                        other => anyhow::bail!("source {j} stream broke: {other:?}"),
                    };
                    anyhow::ensure!(buf.len() == len, "source {j} frame size mismatch");
                    row.push(buf);
                }
                SourceStream::Local(_) => {
                    let b = local_blocks[j].as_ref().unwrap();
                    row.push(Payload::from_shared(b.clone()).slice(offset, offset + len));
                }
            }
        }
        let row_refs: Vec<&[u8]> = row.iter().map(|b| b.as_slice()).collect();
        crate::trace_emit!(
            cpu.clock(),
            cpu.node(),
            crate::trace::EventKind::GemmStart {
                rows: m,
                frame: frame_no
            }
        );
        let parity_bufs = backend.gemm(width, parity_rows, &row_refs)?;
        // The row's m×k gemm is this step's compute, charged before the
        // parity buffers ship so compute paces the outgoing streams.
        compute += cpu.charge(&GfWork::gemm(parity_rows, len));
        crate::trace_emit!(
            cpu.clock(),
            cpu.node(),
            crate::trace::EventKind::GemmEnd {
                rows: m,
                frame: frame_no
            }
        );
        frame_no += 1;
        for (i, pb) in parity_bufs.into_iter().enumerate() {
            match dests[i] {
                ParityDest::Stream(ref mut tx) => tx.send_data(pb)?,
                ParityDest::Store(_) => local_acc[i].extend_from_slice(&pb),
            }
        }
        offset += len;
    }
    // close remote source streams (drain End frames) and parity streams
    for s in &sources {
        if let SourceStream::Remote(rx) = s {
            match rx.recv() {
                Some(Frame::End) => {}
                other => anyhow::bail!("source stream missing End: {other:?}"),
            }
        }
    }
    for (i, d) in dests.iter_mut().enumerate() {
        match d {
            ParityDest::Stream(tx) => tx.finish()?,
            ParityDest::Store(key) => {
                let acc = std::mem::take(&mut local_acc[i]);
                let bytes = acc.len();
                compute += cpu.charge(&GfWork::store(bytes));
                anyhow::ensure!(
                    store.put_unless(*key, acc, failed),
                    "classical encode aborted: node has failed"
                );
                crate::trace_emit!(
                    cpu.clock(),
                    cpu.node(),
                    crate::trace::EventKind::StoreDone {
                        object: key.object.0,
                        index: key.index,
                        bytes
                    }
                );
            }
        }
    }
    Ok(StepStats {
        compute,
        ..Default::default()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::cluster::link::{link, LinkSpec};
    use crate::clock::SimClock;
    use crate::storage::ObjectId;

    fn sim() -> ClockHandle {
        SimClock::handle()
    }

    fn nic(clock: &ClockHandle) -> Arc<RateLimiter> {
        Arc::new(RateLimiter::new(clock.clone(), 1e9))
    }

    fn meter(clock: &ClockHandle, id: NodeId) -> Arc<CpuMeter> {
        Arc::new(CpuMeter::new(clock.clone(), crate::resources::ZeroCost::handle(), id))
    }

    fn node_on(clock: &ClockHandle, id: NodeId) -> NodeHandle {
        NodeHandle::spawn(id, nic(clock), nic(clock), meter(clock, id), DEFAULT_MAX_WORKERS)
    }

    #[test]
    fn put_peek_delete_roundtrip() {
        let c = sim();
        let n = node_on(&c, 0);
        let key = BlockKey::source(ObjectId(1), 0);
        n.put(key, vec![1, 2, 3]).unwrap();
        assert_eq!(*n.peek(key).unwrap().unwrap(), vec![1, 2, 3]);
        assert!(n.delete(key).unwrap());
        assert!(n.peek(key).unwrap().is_none());
    }

    #[test]
    fn upload_receive_moves_block() {
        let c = sim();
        let a = node_on(&c, 0);
        let b = node_on(&c, 1);
        let key = BlockKey::source(ObjectId(1), 0);
        let data: Vec<u8> = (0..100_000u32).map(|i| i as u8).collect();
        a.put(key, data.clone()).unwrap();

        let (tx, rx) = link(a.up.clone(), b.down.clone(), LinkSpec::instant(), 1);
        let (d1, w1) = clock::channel(&c);
        let (d2, w2) = clock::channel(&c);
        b.send(Command::Receive {
            key,
            rx,
            expect_bytes: data.len(),
            done: d2,
        })
        .unwrap();
        a.send(Command::Upload {
            key,
            tx,
            buf_bytes: 4096,
            done: d1,
        })
        .unwrap();
        w1.recv().unwrap().unwrap();
        w2.recv().unwrap().unwrap();
        assert_eq!(*b.peek(key).unwrap().unwrap(), data);
    }

    #[test]
    fn worker_cap_queues_then_completes_all() {
        // A cap of 1 forces the second/third uploads to queue; all three
        // must still complete and deliver correct bytes.
        let c = sim();
        let a = NodeHandle::spawn(0, nic(&c), nic(&c), meter(&c, 0), 1);
        let sinks: Vec<NodeHandle> = (1..4).map(|id| node_on(&c, id)).collect();
        let data: Vec<u8> = (0..50_000u32).map(|i| (i * 3) as u8).collect();
        for i in 0..3 {
            a.put(BlockKey::source(ObjectId(7), i), data.clone()).unwrap();
        }
        let mut waits = Vec::new();
        for (i, sink) in sinks.iter().enumerate() {
            let key = BlockKey::source(ObjectId(7), i);
            let (tx, rx) = link(a.up.clone(), sink.down.clone(), LinkSpec::instant(), 10 + i as u64);
            let (dr, wr) = clock::channel(&c);
            sink.send(Command::Receive {
                key,
                rx,
                expect_bytes: data.len(),
                done: dr,
            })
            .unwrap();
            let (du, wu) = clock::channel(&c);
            a.send(Command::Upload {
                key,
                tx,
                buf_bytes: 4096,
                done: du,
            })
            .unwrap();
            waits.push(wu);
            waits.push(wr);
        }
        // With cap 1 at most one upload runs at a time, but every queued one
        // eventually runs and finishes.
        for w in waits {
            w.recv().unwrap().unwrap();
        }
        for (i, sink) in sinks.iter().enumerate() {
            assert_eq!(
                *sink.peek(BlockKey::source(ObjectId(7), i)).unwrap().unwrap(),
                data,
                "sink {i}"
            );
        }
    }

    #[test]
    fn queue_stall_overflow_prevents_dependency_deadlock() {
        use std::time::Duration;
        // cap = 1: a running Receive waits on an Upload queued behind it on
        // the SAME node. A hard cap would deadlock; the stall overflow must
        // run the Upload after QUEUE_STALL_OVERFLOW of *virtual* time and
        // complete both — instantly in wall-clock terms under SimClock.
        let c = sim();
        let a = NodeHandle::spawn(0, nic(&c), nic(&c), meter(&c, 0), 1);
        let key = BlockKey::source(ObjectId(8), 0);
        let out_key = BlockKey::source(ObjectId(8), 1);
        let data = vec![7u8; 10_000];
        a.put(key, data.clone()).unwrap();
        let (tx, rx) = link(a.up.clone(), a.down.clone(), LinkSpec::instant(), 77);
        let (dr, wr) = clock::channel(&c);
        a.send(Command::Receive {
            key: out_key,
            rx,
            expect_bytes: data.len(),
            done: dr,
        })
        .unwrap();
        let (du, wu) = clock::channel(&c);
        a.send(Command::Upload {
            key,
            tx,
            buf_bytes: 1024,
            done: du,
        })
        .unwrap();
        wr.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        wu.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        assert_eq!(*a.peek(out_key).unwrap().unwrap(), data);
        // the stall overflow fired at a virtual deadline, not a wall one
        assert!(c.now() >= QUEUE_STALL_OVERFLOW);
    }

    #[test]
    fn two_node_pipeline_produces_correct_codeword() {
        // 2-stage chain over a (2,1)-ish toy: node0 head, node1 tail.
        let c = sim();
        let n0 = node_on(&c, 0);
        let n1 = node_on(&c, 1);
        let obj = ObjectId(9);
        let o0: Vec<u8> = (0..8192u32).map(|i| (i * 7) as u8).collect();
        n0.put(BlockKey::source(obj, 0), o0.clone()).unwrap();
        n1.put(BlockKey::source(obj, 0), o0.clone()).unwrap();

        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let (tx, rx) = link(n0.up.clone(), n1.down.clone(), LinkSpec::instant(), 2);
        let (d0, w0) = clock::channel(&c);
        let (d1, w1) = clock::channel(&c);
        n1.send(Command::PipelineStage {
            width: Width::W8,
            locals: vec![BlockKey::source(obj, 0)],
            psi: vec![5],
            xi: vec![9],
            prev: Some(rx),
            next: Vec::new(),
            out_key: Some(BlockKey::coded(obj, 1)),
            buf_bytes: 1024,
            backend: backend.clone(),
            done: d1,
        })
        .unwrap();
        n0.send(Command::PipelineStage {
            width: Width::W8,
            locals: vec![BlockKey::source(obj, 0)],
            psi: vec![3],
            xi: vec![7],
            prev: None,
            next: vec![tx],
            out_key: Some(BlockKey::coded(obj, 0)),
            buf_bytes: 1024,
            backend,
            done: d0,
        })
        .unwrap();
        w0.recv().unwrap().unwrap();
        w1.recv().unwrap().unwrap();

        // c0 = 7*o0 ; c1 = 3*o0 ^ 9*o0
        use crate::gf::tables::mul_bitwise;
        let c0 = n0.peek(BlockKey::coded(obj, 0)).unwrap().unwrap();
        let c1 = n1.peek(BlockKey::coded(obj, 1)).unwrap().unwrap();
        for i in 0..o0.len() {
            assert_eq!(c0[i] as u32, mul_bitwise(7, o0[i] as u32, 8));
            let expect = mul_bitwise(3, o0[i] as u32, 8) ^ mul_bitwise(9, o0[i] as u32, 8);
            assert_eq!(c1[i] as u32, expect);
        }
    }

    #[test]
    fn classical_encode_with_local_source_and_local_parity() {
        let c = sim();
        let coder = node_on(&c, 0);
        let src_node = node_on(&c, 1);
        let parity_dst = node_on(&c, 2);
        let obj = ObjectId(5);
        let block: usize = 32_768;
        let b0: Vec<u8> = (0..block).map(|i| (i * 3) as u8).collect();
        let b1: Vec<u8> = (0..block).map(|i| (i * 5 + 1) as u8).collect();
        coder.put(BlockKey::source(obj, 0), b0.clone()).unwrap(); // local
        src_node.put(BlockKey::source(obj, 1), b1.clone()).unwrap(); // remote

        let backend: BackendHandle = Arc::new(NativeBackend::new());
        // remote source stream
        let (s_tx, s_rx) = link(src_node.up.clone(), coder.down.clone(), LinkSpec::instant(), 3);
        // remote parity stream
        let (p_tx, p_rx) = link(coder.up.clone(), parity_dst.down.clone(), LinkSpec::instant(), 4);

        let (du, wu) = clock::channel(&c);
        src_node
            .send(Command::Upload {
                key: BlockKey::source(obj, 1),
                tx: s_tx,
                buf_bytes: 4096,
                done: du,
            })
            .unwrap();
        let (dr, wr) = clock::channel(&c);
        parity_dst
            .send(Command::Receive {
                key: BlockKey::coded(obj, 3),
                rx: p_rx,
                expect_bytes: block,
                done: dr,
            })
            .unwrap();
        let (dc, wc) = clock::channel(&c);
        coder
            .send(Command::ClassicalEncode {
                width: Width::W8,
                sources: vec![
                    SourceStream::Local(BlockKey::source(obj, 0)),
                    SourceStream::Remote(s_rx),
                ],
                parity_rows: vec![vec![2, 3], vec![4, 5]],
                dests: vec![
                    ParityDest::Store(BlockKey::coded(obj, 2)),
                    ParityDest::Stream(p_tx),
                ],
                buf_bytes: 4096,
                block_bytes: block,
                backend,
                done: dc,
            })
            .unwrap();
        wu.recv().unwrap().unwrap();
        wc.recv().unwrap().unwrap();
        wr.recv().unwrap().unwrap();

        use crate::gf::tables::mul_bitwise;
        let p0 = coder.peek(BlockKey::coded(obj, 2)).unwrap().unwrap();
        let p1 = parity_dst.peek(BlockKey::coded(obj, 3)).unwrap().unwrap();
        for i in 0..block {
            let e0 = mul_bitwise(2, b0[i] as u32, 8) ^ mul_bitwise(3, b1[i] as u32, 8);
            let e1 = mul_bitwise(4, b0[i] as u32, 8) ^ mul_bitwise(5, b1[i] as u32, 8);
            assert_eq!(p0[i] as u32, e0, "parity0 byte {i}");
            assert_eq!(p1[i] as u32, e1, "parity1 byte {i}");
        }
    }

    #[test]
    fn classical_encode_multiple_local_parities() {
        // The generalized ParityDest allows several locally kept outputs —
        // the atomic lowering of a full non-systematic generator needs it.
        let c = sim();
        let coder = node_on(&c, 0);
        let obj = ObjectId(6);
        let block: usize = 8192;
        let b0: Vec<u8> = (0..block).map(|i| (i * 7) as u8).collect();
        coder.put(BlockKey::source(obj, 0), b0.clone()).unwrap();

        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let (dc, wc) = clock::channel(&c);
        coder
            .send(Command::ClassicalEncode {
                width: Width::W8,
                sources: vec![SourceStream::Local(BlockKey::source(obj, 0))],
                parity_rows: vec![vec![1], vec![3]],
                dests: vec![
                    ParityDest::Store(BlockKey::coded(obj, 0)),
                    ParityDest::Store(BlockKey::coded(obj, 1)),
                ],
                buf_bytes: 1024,
                block_bytes: block,
                backend,
                done: dc,
            })
            .unwrap();
        wc.recv().unwrap().unwrap();

        use crate::gf::tables::mul_bitwise;
        let c0 = coder.peek(BlockKey::coded(obj, 0)).unwrap().unwrap();
        let c1 = coder.peek(BlockKey::coded(obj, 1)).unwrap().unwrap();
        assert_eq!(*c0, b0);
        for i in 0..block {
            assert_eq!(c1[i] as u32, mul_bitwise(3, b0[i] as u32, 8), "byte {i}");
        }
    }

    #[test]
    fn upload_frames_are_views_of_the_stored_block() {
        let c = sim();
        let a = node_on(&c, 0);
        let key = BlockKey::source(ObjectId(19), 0);
        let data: Vec<u8> = (0..10_000u32).map(|i| i as u8).collect();
        a.put(key, data.clone()).unwrap();
        let stored = Payload::from_shared(a.store.get(&key).unwrap());

        let (tx, rx) = link(a.up.clone(), nic(&c), LinkSpec::instant(), 41);
        let (d, w) = clock::channel(&c);
        a.send(Command::Upload {
            key,
            tx,
            buf_bytes: 4096,
            done: d,
        })
        .unwrap();
        let mut seen = 0usize;
        loop {
            match rx.recv() {
                Some(Frame::Data(p)) => {
                    assert!(p.shares_buffer(&stored), "frame copied the block");
                    assert_eq!(p.as_slice(), &data[seen..seen + p.len()]);
                    seen += p.len();
                }
                Some(Frame::End) => break,
                None => panic!("stream broke"),
            }
        }
        assert_eq!(seen, data.len());
        w.recv().unwrap().unwrap();
    }

    #[test]
    fn pipeline_fanout_sends_shared_views_not_copies() {
        // A tree interior stage fanning x_out to two children must put the
        // SAME allocation on both links (refcount bump, no memcpy).
        let c = sim();
        let n0 = node_on(&c, 0);
        let obj = ObjectId(20);
        let data = vec![5u8; 4096];
        n0.put(BlockKey::source(obj, 0), data).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let (tx1, rx1) = link(n0.up.clone(), nic(&c), LinkSpec::instant(), 42);
        let (tx2, rx2) = link(n0.up.clone(), nic(&c), LinkSpec::instant(), 43);
        let (d, w) = clock::channel(&c);
        n0.send(Command::PipelineStage {
            width: Width::W8,
            locals: vec![BlockKey::source(obj, 0)],
            psi: vec![3],
            xi: vec![7],
            prev: None,
            next: vec![tx1, tx2],
            out_key: None,
            buf_bytes: 1024,
            backend,
            done: d,
        })
        .unwrap();
        let mut frames = 0;
        loop {
            match (rx1.recv(), rx2.recv()) {
                (Some(Frame::Data(p1)), Some(Frame::Data(p2))) => {
                    assert!(p1.shares_buffer(&p2), "fan-out duplicated the frame");
                    assert_eq!(p1.as_slice(), p2.as_slice());
                    frames += 1;
                }
                (Some(Frame::End), Some(Frame::End)) => break,
                other => panic!("streams diverged: {other:?}"),
            }
        }
        assert_eq!(frames, 4);
        w.recv().unwrap().unwrap();
    }

    #[test]
    fn failed_node_rejects_commands_and_loses_blocks() {
        let c = sim();
        let n = node_on(&c, 0);
        let key = BlockKey::source(ObjectId(11), 0);
        n.put(key, vec![1, 2, 3]).unwrap();
        n.fail();
        assert!(n.is_failed());
        assert!(n.put(key, vec![4]).is_err());
        assert!(n.peek(key).is_err());
        n.revive();
        assert!(!n.is_failed());
        // revived empty: the crash lost the simulated disk
        assert!(n.peek(key).unwrap().is_none());
        n.put(key, vec![9]).unwrap();
        assert_eq!(*n.peek(key).unwrap().unwrap(), vec![9]);
    }

    #[test]
    fn crash_rejects_queued_commands() {
        use std::time::Duration;
        // cap = 1: a Receive blocked on a silent link occupies the slot, an
        // Upload queues behind it; the crash must reject the queued Upload
        // (error, not hang) even though the running worker never finishes
        // on its own. Real clock: the 100 ms stall window must not elapse
        // before the crash lands, which a SimClock would fast-forward.
        let c = crate::clock::RealClock::handle();
        let a = NodeHandle::spawn(0, nic(&c), nic(&c), meter(&c, 0), 1);
        let key = BlockKey::source(ObjectId(12), 0);
        a.put(key, vec![5; 100]).unwrap();
        let (hold_tx, hold_rx) = link(nic(&c), a.down.clone(), LinkSpec::instant(), 21);
        let (dr, _wr) = clock::channel(&c);
        a.send(Command::Receive {
            key: BlockKey::source(ObjectId(12), 1),
            rx: hold_rx,
            expect_bytes: 0,
            done: dr,
        })
        .unwrap();
        let (up_tx, _up_rx) = link(a.up.clone(), nic(&c), LinkSpec::instant(), 22);
        let (du, wu) = clock::channel(&c);
        a.send(Command::Upload {
            key,
            tx: up_tx,
            buf_bytes: 64,
            done: du,
        })
        .unwrap();
        a.fail();
        let res = wu.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(res.unwrap_err().to_string().contains("failed"));
        drop(hold_tx); // release the blocked worker so shutdown can join
    }

    #[test]
    fn upload_missing_block_reports_error() {
        let c = sim();
        let a = node_on(&c, 0);
        let b = node_on(&c, 1);
        let (tx, _rx) = link(a.up.clone(), b.down.clone(), LinkSpec::instant(), 5);
        let (d, w) = clock::channel(&c);
        a.send(Command::Upload {
            key: BlockKey::source(ObjectId(404), 0),
            tx,
            buf_bytes: 1024,
            done: d,
        })
        .unwrap();
        assert!(w.recv().unwrap().is_err());
    }

    #[test]
    fn pipeline_stage_charges_modeled_compute_in_virtual_time() {
        use crate::resources::{UniformCost, ZeroCost};
        // One-node chain head with a cost model: the stage must occupy
        // virtual time for its GF work and report it in StepStats; the
        // same command under ZeroCost must report zero compute.
        let run = |model: crate::resources::CostModelHandle| -> (Tick, StepStats) {
            let c = sim();
            let n = NodeHandle::spawn(
                0,
                nic(&c),
                nic(&c),
                Arc::new(CpuMeter::new(c.clone(), model, 0)),
                DEFAULT_MAX_WORKERS,
            );
            let obj = ObjectId(13);
            let data = vec![3u8; 64 * 1024];
            n.put(BlockKey::source(obj, 0), data).unwrap();
            let backend: BackendHandle = Arc::new(NativeBackend::new());
            let (d, w) = clock::channel(&c);
            n.send(Command::PipelineStage {
                width: Width::W8,
                locals: vec![BlockKey::source(obj, 0)],
                psi: vec![5],
                xi: vec![9],
                prev: None,
                next: Vec::new(),
                out_key: Some(BlockKey::coded(obj, 0)),
                buf_bytes: 16 * 1024,
                backend,
                done: d,
            })
            .unwrap();
            let stats = w.recv().unwrap().unwrap();
            (c.now(), stats)
        };
        let (t_zero, s_zero) = run(ZeroCost::handle());
        assert_eq!(s_zero.compute, Tick::ZERO);
        let (t_cost, s_cost) = run(UniformCost::handle());
        assert!(s_cost.compute > Tick::ZERO, "no compute charged");
        assert!(
            t_cost > t_zero,
            "cost model added no virtual time: {t_cost:?} vs {t_zero:?}"
        );
        // the stage's virtual occupancy includes at least its compute
        assert!(t_cost >= s_cost.compute);
    }
}
