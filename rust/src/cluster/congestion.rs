//! Congestion injection — the simulation equivalent of the paper's `netem`
//! configuration (Section VI-D): bandwidth clamped from 1 Gbps to 500 Mbps
//! and 100 ms ± 10 ms latency added on congested nodes.

use std::time::Duration;

/// A congestion profile applied to a node's NICs and links.
#[derive(Clone, Debug)]
pub struct CongestionSpec {
    /// Clamped NIC bandwidth (both directions), bytes/second.
    pub bytes_per_sec: f64,
    /// Added one-way latency on links touching the node.
    pub extra_latency: Duration,
    /// Uniform jitter amplitude on the added latency.
    pub jitter: Duration,
}

impl CongestionSpec {
    /// The paper's exact netem profile: 500 Mbps + 100 ms ± 10 ms.
    pub fn paper_netem() -> Self {
        Self {
            bytes_per_sec: 62.5e6, // 500 Mbps
            extra_latency: Duration::from_millis(100),
            jitter: Duration::from_millis(10),
        }
    }

    /// A milder profile for fast test runs (same shape, smaller numbers).
    pub fn mild() -> Self {
        Self {
            bytes_per_sec: 62.5e6,
            extra_latency: Duration::from_millis(10),
            jitter: Duration::from_millis(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_profile_values() {
        let p = CongestionSpec::paper_netem();
        assert!((p.bytes_per_sec - 62.5e6).abs() < 1.0);
        assert_eq!(p.extra_latency, Duration::from_millis(100));
        assert_eq!(p.jitter, Duration::from_millis(10));
    }
}
