//! Simulated distributed-storage cluster.
//!
//! Substitution for the paper's two testbeds (50 HP ThinClients on 1 GbE,
//! and 16 Amazon EC2 small instances — see DESIGN.md §3): every storage
//! node is a real OS thread with a block store and a command protocol;
//! every byte of payload really moves between threads through rate-limited,
//! latency-delayed channels. Per-node NIC token buckets reproduce the
//! phenomenon the paper's analysis hinges on — a node's aggregate up/down
//! bandwidth is finite, so k parallel downloads into one coding node cost
//! ~k block-times (eq. 1) while the pipeline's node-to-node hops overlap
//! (eq. 2).
//!
//! Congestion (the paper's `netem` runs: 1 Gbps → 500 Mbps plus 100±10 ms
//! latency) is applied per node via [`congestion`].
//!
//! All of it runs on a pluggable [`crate::clock::Clock`] carried by the
//! [`ClusterSpec`]: a `RealClock` gives the paper-faithful wall-clock
//! testbeds, a `SimClock` turns the identical cluster into a deterministic
//! discrete-event simulation where a 50-node, multi-hour trace costs
//! milliseconds (see `ClusterSpec::sim` and the `workload` module).

pub mod congestion;
pub mod link;
pub mod network;
pub mod nic;
pub mod node;
pub mod runtime;

pub use congestion::CongestionSpec;
pub use link::{Frame, LinkSpec, Payload, Rx, Tx};
pub use network::{Cluster, ClusterSpec};
pub use runtime::RuntimeKind;
pub use nic::{RateLimiter, Reservation};
pub use node::{
    Command, NodeHandle, ParityDest, SourceStream, StepResult, StepStats, DEFAULT_MAX_WORKERS,
    QUEUE_STALL_OVERFLOW,
};

/// Node identifier within a cluster.
pub type NodeId = usize;
