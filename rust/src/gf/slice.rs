//! Bulk GF slice operations — the native encoding hot path.
//!
//! These are the Rust equivalents of Jerasure's *region* operations, the
//! inner loop of every encoder in the crate when the native backend is
//! selected (the PJRT backend runs the same math inside the AOT Pallas
//! kernels instead).
//!
//! The key trick (same as Jerasure's `MULT_TABLE` / gf-complete's `SPLIT`):
//! a slice is always multiplied by ONE coefficient, so we pre-expand that
//! coefficient into small product tables and stream the payload once. As
//! of PR 6 the per-byte work is delegated to [`super::simd`] — the
//! process-wide [`Kernel`] (scalar 256-entry tables, or split-nibble
//! `PSHUFB`/`TBL` vector shuffles where the CPU supports them) is picked
//! once by [`Kernel::active`] and every slice op streams through it.
//!
//! The [`GfWork`] reported is computed from the coefficient class and the
//! payload length *before* dispatch, so it is identical on every kernel —
//! `ZeroCost` pricing, `SimClock` determinism and the dataplane's
//! per-frame charges do not depend on which instructions ran.

use super::field::{Gf256, Gf65536, GfElem};
use super::simd::{self, Kernel};
use crate::resources::GfWork;

/// `dst[i] ^= c * src[i]` — the multiply-accumulate at the heart of both the
/// classical parity generation and the RapidRAID pipeline stage.
///
/// Every op reports the [`GfWork`] it *actually* performed — a zero
/// coefficient does nothing, a one-coefficient takes the XOR shortcut, and
/// only the general case pays a table MAC pass — so compute stops being
/// invisible to the resource model: the same shortcut rules feed the
/// dataplane's per-frame charges ([`GfWork::coeff`]) and the cost models
/// price what the kernel really did.
pub trait SliceOps: GfElem {
    /// dst ^= c * src (elementwise, GF multiply); returns the work done.
    fn mul_slice_xor(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork;
    /// dst = c * src (elementwise, GF multiply); returns the work done.
    fn mul_slice(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork;
}

/// Raw byte view of a symbol slice (both fields are plain little-endian
/// integer wrappers, so the reinterpretation is layout-exact).
#[inline]
fn as_bytes<F: GfElem>(s: &[F]) -> &[u8] {
    // SAFETY: Gf256/Gf65536 are transparent u8/u16 wrappers; any byte
    // pattern is a valid symbol and size_of_val gives the exact byte count.
    unsafe { std::slice::from_raw_parts(s.as_ptr() as *const u8, std::mem::size_of_val(s)) }
}

/// Mutable raw byte view of a symbol slice.
#[inline]
fn as_bytes_mut<F: GfElem>(s: &mut [F]) -> &mut [u8] {
    // SAFETY: as above.
    unsafe { std::slice::from_raw_parts_mut(s.as_mut_ptr() as *mut u8, std::mem::size_of_val(s)) }
}

impl SliceOps for Gf256 {
    fn mul_slice_xor(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork {
        assert_eq!(src.len(), dst.len());
        if c.0 == 0 {
            return GfWork::ZERO;
        }
        if c.0 == 1 {
            return xor_slice(src, dst);
        }
        let n = src.len();
        simd::mul_xor8(Kernel::active(), c.0, as_bytes(src), as_bytes_mut(dst));
        GfWork::mac(n)
    }

    fn mul_slice(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork {
        assert_eq!(src.len(), dst.len());
        if c.0 == 0 {
            dst.fill(Gf256::ZERO);
            return GfWork::xor(dst.len());
        }
        if c.0 == 1 {
            dst.copy_from_slice(src);
            return GfWork::xor(dst.len());
        }
        simd::mul8(Kernel::active(), c.0, as_bytes(src), as_bytes_mut(dst));
        GfWork::mac(dst.len())
    }
}

impl SliceOps for Gf65536 {
    fn mul_slice_xor(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork {
        assert_eq!(src.len(), dst.len());
        if c.0 == 0 {
            return GfWork::ZERO;
        }
        if c.0 == 1 {
            return xor_slice(src, dst);
        }
        simd::mul_xor16(Kernel::active(), c.0, as_bytes(src), as_bytes_mut(dst));
        GfWork::mac(2 * dst.len())
    }

    fn mul_slice(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork {
        assert_eq!(src.len(), dst.len());
        if c.0 == 0 {
            dst.fill(Gf65536::ZERO);
            return GfWork::xor(2 * dst.len());
        }
        if c.0 == 1 {
            dst.copy_from_slice(src);
            return GfWork::xor(2 * dst.len());
        }
        simd::mul16(Kernel::active(), c.0, as_bytes(src), as_bytes_mut(dst));
        GfWork::mac(2 * dst.len())
    }
}

/// `dst[i] ^= c * src[i]` for any field implementing [`SliceOps`].
#[inline]
pub fn mul_slice_xor<F: SliceOps>(c: F, src: &[F], dst: &mut [F]) -> GfWork {
    F::mul_slice_xor(c, src, dst)
}

/// `dst[i] = c * src[i]` for any field implementing [`SliceOps`].
#[inline]
pub fn mul_slice<F: SliceOps>(c: F, src: &[F], dst: &mut [F]) -> GfWork {
    F::mul_slice(c, src, dst)
}

/// Plain `dst ^= src` — in GF(2^w) field addition *is* XOR, so the pass
/// runs on the raw byte views: `u64` words on the scalar kernel, vector
/// XOR on the SIMD kernels, any alignment.
pub fn xor_slice<F: GfElem>(src: &[F], dst: &mut [F]) -> GfWork {
    assert_eq!(src.len(), dst.len());
    let n = std::mem::size_of_val(dst);
    simd::xor_bytes(Kernel::active(), as_bytes(src), as_bytes_mut(dst));
    GfWork::xor(n)
}

/// Reinterpret a byte buffer as GF(2^8) symbols (zero-copy).
#[inline]
pub fn bytes_as_gf256(bytes: &[u8]) -> &[Gf256] {
    // SAFETY: Gf256 is repr(transparent)-equivalent (single u8 field, same
    // size/alignment); the transmute only changes the nominal type.
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const Gf256, bytes.len()) }
}

/// Reinterpret a mutable byte buffer as GF(2^8) symbols (zero-copy).
#[inline]
pub fn bytes_as_gf256_mut(bytes: &mut [u8]) -> &mut [Gf256] {
    // SAFETY: as above.
    unsafe { std::slice::from_raw_parts_mut(bytes.as_mut_ptr() as *mut Gf256, bytes.len()) }
}

#[inline]
fn gf16_borrowable(bytes: &[u8]) -> bool {
    bytes.len() % 2 == 0 && bytes.as_ptr() as usize % 2 == 0
}

/// GF(2^16) read view of a byte buffer: zero-copy when the buffer has even
/// length and a 2-aligned pointer (every `vec![0u8; n]` payload in
/// practice), otherwise a checked copy of the little-endian word stream —
/// an odd trailing byte becomes the low byte of a zero-padded final
/// symbol. Dereferences to `[Gf65536]` either way.
#[derive(Debug)]
pub enum Gf16View<'a> {
    /// Zero-copy reinterpretation of the caller's bytes.
    Borrowed(&'a [Gf65536]),
    /// Copied symbols (odd length or misaligned pointer).
    Owned(Vec<Gf65536>),
}

impl std::ops::Deref for Gf16View<'_> {
    type Target = [Gf65536];
    fn deref(&self) -> &[Gf65536] {
        match self {
            Gf16View::Borrowed(s) => s,
            Gf16View::Owned(v) => v,
        }
    }
}

impl Gf16View<'_> {
    /// Whether this view reinterprets the caller's buffer in place.
    pub fn is_borrowed(&self) -> bool {
        matches!(self, Gf16View::Borrowed(_))
    }
}

/// Reinterpret a byte buffer as GF(2^16) symbols: zero-copy where layout
/// allows, copy fallback otherwise (see [`Gf16View`]).
pub fn bytes_as_gf65536(bytes: &[u8]) -> Gf16View<'_> {
    if bytes.is_empty() {
        // an empty &[u8]'s pointer may be odd — don't reinterpret it
        return Gf16View::Borrowed(&[]);
    }
    if gf16_borrowable(bytes) {
        // SAFETY: length/alignment checked; u16 has no invalid bit patterns.
        Gf16View::Borrowed(unsafe {
            std::slice::from_raw_parts(bytes.as_ptr() as *const Gf65536, bytes.len() / 2)
        })
    } else {
        let mut v = Vec::with_capacity(bytes.len().div_ceil(2));
        let mut it = bytes.chunks_exact(2);
        for pair in &mut it {
            v.push(Gf65536(u16::from_le_bytes([pair[0], pair[1]])));
        }
        if let [last] = it.remainder() {
            v.push(Gf65536(*last as u16));
        }
        Gf16View::Owned(v)
    }
}

enum Gf16ViewMutInner<'a> {
    Borrowed(&'a mut [Gf65536]),
    /// Copy-out / write-back: `symbols` is edited in place and flushed to
    /// `bytes` on drop. An odd trailing byte round-trips only the low byte
    /// of its zero-padded final symbol.
    Copied {
        bytes: &'a mut [u8],
        symbols: Vec<Gf65536>,
    },
}

/// GF(2^16) write view of a byte buffer: zero-copy when even/2-aligned,
/// otherwise a copy whose edits are written back (little-endian) when the
/// view drops. Dereferences to `[Gf65536]`/`mut [Gf65536]` either way.
pub struct Gf16ViewMut<'a> {
    inner: Gf16ViewMutInner<'a>,
}

impl std::ops::Deref for Gf16ViewMut<'_> {
    type Target = [Gf65536];
    fn deref(&self) -> &[Gf65536] {
        match &self.inner {
            Gf16ViewMutInner::Borrowed(s) => s,
            Gf16ViewMutInner::Copied { symbols, .. } => symbols,
        }
    }
}

impl std::ops::DerefMut for Gf16ViewMut<'_> {
    fn deref_mut(&mut self) -> &mut [Gf65536] {
        match &mut self.inner {
            Gf16ViewMutInner::Borrowed(s) => s,
            Gf16ViewMutInner::Copied { symbols, .. } => symbols,
        }
    }
}

impl Gf16ViewMut<'_> {
    /// Whether this view edits the caller's buffer in place.
    pub fn is_borrowed(&self) -> bool {
        matches!(self.inner, Gf16ViewMutInner::Borrowed(_))
    }
}

impl Drop for Gf16ViewMut<'_> {
    fn drop(&mut self) {
        if let Gf16ViewMutInner::Copied { bytes, symbols } = &mut self.inner {
            for (chunk, sym) in bytes.chunks_mut(2).zip(symbols.iter()) {
                let le = sym.0.to_le_bytes();
                // a 1-byte tail chunk persists only the low byte
                chunk.copy_from_slice(&le[..chunk.len()]);
            }
        }
    }
}

/// Mutable GF(2^16) view of a byte buffer: zero-copy where layout allows,
/// checked copy + drop-time write-back otherwise (see [`Gf16ViewMut`]).
pub fn bytes_as_gf65536_mut(bytes: &mut [u8]) -> Gf16ViewMut<'_> {
    if bytes.is_empty() {
        // as in `bytes_as_gf65536`: never reinterpret a possibly-odd
        // dangling pointer, even at length zero
        return Gf16ViewMut {
            inner: Gf16ViewMutInner::Borrowed(&mut []),
        };
    }
    if gf16_borrowable(bytes) {
        // SAFETY: length/alignment checked; u16 has no invalid bit patterns.
        let s = unsafe {
            std::slice::from_raw_parts_mut(bytes.as_mut_ptr() as *mut Gf65536, bytes.len() / 2)
        };
        Gf16ViewMut {
            inner: Gf16ViewMutInner::Borrowed(s),
        }
    } else {
        let symbols = match bytes_as_gf65536(bytes) {
            Gf16View::Owned(v) => v,
            // bytes fail the borrow check here too, so the read view copied
            Gf16View::Borrowed(s) => s.to_vec(),
        };
        Gf16ViewMut {
            inner: Gf16ViewMutInner::Copied { bytes, symbols },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::tables::mul_bitwise;
    use crate::util::rng::SplitMix64;

    #[test]
    fn mul_slice_xor_gf256_matches_scalar() {
        let mut rng = SplitMix64::new(3);
        for c in [0u8, 1, 2, 97, 255] {
            let src: Vec<Gf256> = (0..1000).map(|_| Gf256(rng.next_u64() as u8)).collect();
            let mut dst: Vec<Gf256> = (0..1000).map(|_| Gf256(rng.next_u64() as u8)).collect();
            let before = dst.clone();
            mul_slice_xor(Gf256(c), &src, &mut dst);
            for i in 0..1000 {
                let expect = before[i].0 ^ mul_bitwise(c as u32, src[i].0 as u32, 8) as u8;
                assert_eq!(dst[i].0, expect, "c={c} i={i}");
            }
        }
    }

    #[test]
    fn mul_slice_xor_gf65536_matches_scalar() {
        let mut rng = SplitMix64::new(4);
        for c in [0u16, 1, 2, 0x1234, 0xFFFF] {
            let src: Vec<Gf65536> = (0..500).map(|_| Gf65536(rng.next_u64() as u16)).collect();
            let mut dst: Vec<Gf65536> = (0..500).map(|_| Gf65536(rng.next_u64() as u16)).collect();
            let before = dst.clone();
            mul_slice_xor(Gf65536(c), &src, &mut dst);
            for i in 0..500 {
                let expect = before[i].0 ^ mul_bitwise(c as u32, src[i].0 as u32, 16) as u16;
                assert_eq!(dst[i].0, expect, "c={c} i={i}");
            }
        }
    }

    #[test]
    fn mul_slice_overwrites() {
        let src = vec![Gf256(7); 64];
        let mut dst = vec![Gf256(0xAA); 64];
        mul_slice(Gf256(3), &src, &mut dst);
        let expect = Gf256(3).mul(Gf256(7));
        assert!(dst.iter().all(|&d| d == expect));
    }

    #[test]
    fn mul_slice_by_zero_and_one() {
        let src: Vec<Gf256> = (0..100).map(|i| Gf256(i as u8)).collect();
        let mut dst = vec![Gf256(0x55); 100];
        mul_slice(Gf256(0), &src, &mut dst);
        assert!(dst.iter().all(|&d| d == Gf256::ZERO));
        mul_slice(Gf256(1), &src, &mut dst);
        assert_eq!(dst, src);
    }

    #[test]
    fn xor_slice_is_involution() {
        let mut rng = SplitMix64::new(5);
        let src: Vec<Gf256> = (0..256).map(|_| Gf256(rng.next_u64() as u8)).collect();
        let orig: Vec<Gf256> = (0..256).map(|_| Gf256(rng.next_u64() as u8)).collect();
        let mut dst = orig.clone();
        xor_slice(&src, &mut dst);
        xor_slice(&src, &mut dst);
        assert_eq!(dst, orig);
    }

    #[test]
    fn xor_slice_matches_elementwise_add_both_widths() {
        let mut rng = SplitMix64::new(51);
        // odd length exercises the word-pass tail
        let src: Vec<Gf65536> = (0..251).map(|_| Gf65536(rng.next_u64() as u16)).collect();
        let orig: Vec<Gf65536> = (0..251).map(|_| Gf65536(rng.next_u64() as u16)).collect();
        let mut dst = orig.clone();
        xor_slice(&src, &mut dst);
        for i in 0..src.len() {
            assert_eq!(dst[i], orig[i].add(src[i]), "i={i}");
        }
    }

    #[test]
    fn byte_views_roundtrip() {
        let bytes: Vec<u8> = (0..64).collect();
        let view = bytes_as_gf256(&bytes);
        assert_eq!(view.len(), 64);
        assert_eq!(view[10], Gf256(10));
        let wide = bytes_as_gf65536(&bytes);
        assert!(wide.is_borrowed());
        assert_eq!(wide.len(), 32);
        assert_eq!(wide[0], Gf65536(u16::from_le_bytes([0, 1])));
    }

    #[test]
    fn gf16_view_copies_odd_and_unaligned_buffers() {
        // odd length: copy fallback, zero-padded final symbol
        let odd: Vec<u8> = vec![0x11, 0x22, 0x33];
        let v = bytes_as_gf65536(&odd);
        assert!(!v.is_borrowed());
        assert_eq!(v.len(), 2);
        assert_eq!(v[0], Gf65536(0x2211));
        assert_eq!(v[1], Gf65536(0x0033));
        // misaligned pointer: slice a 2-aligned Vec at an odd offset
        let buf: Vec<u8> = (0..9u8).collect();
        let off = (buf.as_ptr() as usize % 2 == 0) as usize; // odd address
        let sub = &buf[off..off + 4];
        let v = bytes_as_gf65536(sub);
        assert_eq!(v.len(), 2);
        assert_eq!(v[0], Gf65536(u16::from_le_bytes([sub[0], sub[1]])));
        // empty is a fine borrow
        assert!(bytes_as_gf65536(&[]).is_borrowed());
    }

    #[test]
    fn gf16_view_mut_borrows_and_edits_in_place() {
        let mut bytes = vec![0u8; 8];
        {
            let mut v = bytes_as_gf65536_mut(&mut bytes);
            assert!(v.is_borrowed());
            v[1] = Gf65536(0xBEEF);
        }
        assert_eq!(&bytes[2..4], &0xBEEFu16.to_le_bytes());
    }

    #[test]
    fn gf16_view_mut_writes_back_copied_buffers() {
        // odd length: edits flush on drop; the tail symbol persists its
        // low byte only
        let mut bytes = vec![0u8; 5];
        {
            let mut v = bytes_as_gf65536_mut(&mut bytes);
            assert!(!v.is_borrowed());
            assert_eq!(v.len(), 3);
            v[0] = Gf65536(0x1234);
            v[2] = Gf65536(0xAB99);
        }
        assert_eq!(bytes, vec![0x34, 0x12, 0, 0, 0x99]);
        // misaligned: same write-back through the copy
        let mut buf = vec![0u8; 9];
        let off = (buf.as_ptr() as usize % 2 == 0) as usize;
        {
            let mut v = bytes_as_gf65536_mut(&mut buf[off..off + 4]);
            assert!(!v.is_borrowed());
            v[0] = Gf65536(0x5678);
        }
        assert_eq!(&buf[off..off + 2], &0x5678u16.to_le_bytes());
    }

    #[test]
    fn ops_report_the_work_actually_done() {
        let src = vec![Gf256(7); 100];
        let mut dst = vec![Gf256(1); 100];
        // zero coefficient: the op skips everything and reports nothing
        assert_eq!(mul_slice_xor(Gf256(0), &src, &mut dst), GfWork::ZERO);
        // one: the XOR shortcut
        assert_eq!(mul_slice_xor(Gf256(1), &src, &mut dst), GfWork::xor(100));
        // general: one MAC pass over the payload bytes
        assert_eq!(mul_slice_xor(Gf256(5), &src, &mut dst), GfWork::mac(100));
        // GF(2^16) counts bytes, not symbols
        let src16 = vec![Gf65536(9); 50];
        let mut dst16 = vec![Gf65536(0); 50];
        assert_eq!(mul_slice_xor(Gf65536(3), &src16, &mut dst16), GfWork::mac(100));
        assert_eq!(xor_slice(&src16, &mut dst16), GfWork::xor(100));
        assert_eq!(mul_slice(Gf256(0), &src, &mut dst), GfWork::xor(100));
    }

    #[test]
    fn slice_linearity() {
        // c*(x ⊕ y) == c*x ⊕ c*y at the slice level.
        let mut rng = SplitMix64::new(6);
        let x: Vec<Gf256> = (0..333).map(|_| Gf256(rng.next_u64() as u8)).collect();
        let y: Vec<Gf256> = (0..333).map(|_| Gf256(rng.next_u64() as u8)).collect();
        let c = Gf256(0x53);
        let xy: Vec<Gf256> = x.iter().zip(&y).map(|(a, b)| a.add(*b)).collect();
        let mut lhs = vec![Gf256::ZERO; 333];
        mul_slice(c, &xy, &mut lhs);
        let mut rhs = vec![Gf256::ZERO; 333];
        mul_slice(c, &x, &mut rhs);
        mul_slice_xor(c, &y, &mut rhs);
        assert_eq!(lhs, rhs);
    }
}
