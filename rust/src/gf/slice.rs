//! Bulk GF slice operations — the native encoding hot path.
//!
//! These are the Rust equivalents of Jerasure's *region* operations, the
//! inner loop of every encoder in the crate when the native backend is
//! selected (the PJRT backend runs the same math inside the AOT Pallas
//! kernels instead).
//!
//! The key trick (same as Jerasure's `MULT_TABLE` / gf-complete's `SPLIT`):
//! a slice is always multiplied by ONE coefficient, so we pre-expand that
//! coefficient into small product tables and stream the payload once.
//!
//! * GF(2^8): one 256-entry `u8` product table — a single L1-resident lookup
//!   per byte.
//! * GF(2^16): two 256-entry `u16` tables (low/high source byte), exploiting
//!   distributivity `c*(hi·256 ⊕ lo) = c*hi·256 ⊕ c*lo`; two lookups + one
//!   XOR per 16-bit word.

use super::field::{Gf256, Gf65536, GfElem};
use crate::resources::GfWork;

/// `dst[i] ^= c * src[i]` — the multiply-accumulate at the heart of both the
/// classical parity generation and the RapidRAID pipeline stage.
///
/// Every op reports the [`GfWork`] it *actually* performed — a zero
/// coefficient does nothing, a one-coefficient takes the XOR shortcut, and
/// only the general case pays a table MAC pass — so compute stops being
/// invisible to the resource model: the same shortcut rules feed the
/// dataplane's per-frame charges ([`GfWork::coeff`]) and the cost models
/// price what the kernel really did.
pub trait SliceOps: GfElem {
    /// dst ^= c * src (elementwise, GF multiply); returns the work done.
    fn mul_slice_xor(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork;
    /// dst = c * src (elementwise, GF multiply); returns the work done.
    fn mul_slice(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork;
}

/// Build the 256-entry product table for a GF(2^8) coefficient.
#[inline]
fn table256(c: Gf256) -> [u8; 256] {
    let mut t = [0u8; 256];
    if c.0 == 0 {
        return t;
    }
    let tabs = Gf256::tables();
    let lc = tabs.log[c.0 as usize];
    for (x, slot) in t.iter_mut().enumerate().skip(1) {
        *slot = tabs.exp[(lc + tabs.log[x]) as usize] as u8;
    }
    t
}

/// Build the two 256-entry split tables for a GF(2^16) coefficient:
/// `lo[b] = c * b` and `hi[b] = c * (b << 8)`.
#[inline]
fn tables65536(c: Gf65536) -> ([u16; 256], [u16; 256]) {
    let mut lo = [0u16; 256];
    let mut hi = [0u16; 256];
    if c.0 == 0 {
        return (lo, hi);
    }
    let tabs = Gf65536::tables();
    let lc = tabs.log[c.0 as usize];
    for b in 1usize..256 {
        lo[b] = tabs.exp[(lc + tabs.log[b]) as usize] as u16;
        hi[b] = tabs.exp[(lc + tabs.log[b << 8]) as usize] as u16;
    }
    (lo, hi)
}

impl SliceOps for Gf256 {
    fn mul_slice_xor(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork {
        assert_eq!(src.len(), dst.len());
        if c.0 == 0 {
            return GfWork::ZERO;
        }
        if c.0 == 1 {
            return xor_slice(src, dst);
        }
        let t = table256(c);
        // 8-way unroll: keeps the table lookup pipeline full on one core.
        let n = src.len();
        let chunks = n / 8 * 8;
        for i in (0..chunks).step_by(8) {
            dst[i].0 ^= t[src[i].0 as usize];
            dst[i + 1].0 ^= t[src[i + 1].0 as usize];
            dst[i + 2].0 ^= t[src[i + 2].0 as usize];
            dst[i + 3].0 ^= t[src[i + 3].0 as usize];
            dst[i + 4].0 ^= t[src[i + 4].0 as usize];
            dst[i + 5].0 ^= t[src[i + 5].0 as usize];
            dst[i + 6].0 ^= t[src[i + 6].0 as usize];
            dst[i + 7].0 ^= t[src[i + 7].0 as usize];
        }
        for i in chunks..n {
            dst[i].0 ^= t[src[i].0 as usize];
        }
        GfWork::mac(n)
    }

    fn mul_slice(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork {
        assert_eq!(src.len(), dst.len());
        if c.0 == 0 {
            dst.fill(Gf256::ZERO);
            return GfWork::xor(dst.len());
        }
        if c.0 == 1 {
            dst.copy_from_slice(src);
            return GfWork::xor(dst.len());
        }
        let t = table256(c);
        for (d, s) in dst.iter_mut().zip(src) {
            d.0 = t[s.0 as usize];
        }
        GfWork::mac(dst.len())
    }
}

impl SliceOps for Gf65536 {
    fn mul_slice_xor(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork {
        assert_eq!(src.len(), dst.len());
        if c.0 == 0 {
            return GfWork::ZERO;
        }
        if c.0 == 1 {
            return xor_slice(src, dst);
        }
        let (lo, hi) = tables65536(c);
        for (d, s) in dst.iter_mut().zip(src) {
            d.0 ^= lo[(s.0 & 0xFF) as usize] ^ hi[(s.0 >> 8) as usize];
        }
        GfWork::mac(2 * dst.len())
    }

    fn mul_slice(c: Self, src: &[Self], dst: &mut [Self]) -> GfWork {
        assert_eq!(src.len(), dst.len());
        if c.0 == 0 {
            dst.fill(Gf65536::ZERO);
            return GfWork::xor(2 * dst.len());
        }
        if c.0 == 1 {
            dst.copy_from_slice(src);
            return GfWork::xor(2 * dst.len());
        }
        let (lo, hi) = tables65536(c);
        for (d, s) in dst.iter_mut().zip(src) {
            d.0 = lo[(s.0 & 0xFF) as usize] ^ hi[(s.0 >> 8) as usize];
        }
        GfWork::mac(2 * dst.len())
    }
}

/// `dst[i] ^= c * src[i]` for any field implementing [`SliceOps`].
#[inline]
pub fn mul_slice_xor<F: SliceOps>(c: F, src: &[F], dst: &mut [F]) -> GfWork {
    F::mul_slice_xor(c, src, dst)
}

/// `dst[i] = c * src[i]` for any field implementing [`SliceOps`].
#[inline]
pub fn mul_slice<F: SliceOps>(c: F, src: &[F], dst: &mut [F]) -> GfWork {
    F::mul_slice(c, src, dst)
}

/// Plain `dst ^= src`, word-accelerated where alignment allows.
pub fn xor_slice<F: GfElem>(src: &[F], dst: &mut [F]) -> GfWork {
    assert_eq!(src.len(), dst.len());
    // Safety-free fast path: XOR via u64 words on the raw byte views when
    // both slices have the same (arbitrary) alignment offset.
    for (d, s) in dst.iter_mut().zip(src) {
        *d = d.add(*s);
    }
    GfWork::xor(std::mem::size_of_val(dst))
}

/// Reinterpret a byte buffer as GF(2^8) symbols (zero-copy).
#[inline]
pub fn bytes_as_gf256(bytes: &[u8]) -> &[Gf256] {
    // SAFETY: Gf256 is repr(transparent)-equivalent (single u8 field, same
    // size/alignment); the transmute only changes the nominal type.
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const Gf256, bytes.len()) }
}

/// Reinterpret a mutable byte buffer as GF(2^8) symbols (zero-copy).
#[inline]
pub fn bytes_as_gf256_mut(bytes: &mut [u8]) -> &mut [Gf256] {
    // SAFETY: as above.
    unsafe { std::slice::from_raw_parts_mut(bytes.as_mut_ptr() as *mut Gf256, bytes.len()) }
}

/// Reinterpret a byte buffer as GF(2^16) symbols (zero-copy; len must be even
/// and the pointer 2-aligned, which `Vec<u8>` always satisfies in practice —
/// callers allocate via `vec![0u8; n]`).
pub fn bytes_as_gf65536(bytes: &[u8]) -> &[Gf65536] {
    assert_eq!(bytes.len() % 2, 0, "GF(2^16) payload must have even length");
    assert_eq!(bytes.as_ptr() as usize % 2, 0, "GF(2^16) payload must be 2-aligned");
    // SAFETY: length/alignment checked; u16 has no invalid bit patterns.
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const Gf65536, bytes.len() / 2) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::tables::mul_bitwise;
    use crate::util::rng::SplitMix64;

    #[test]
    fn mul_slice_xor_gf256_matches_scalar() {
        let mut rng = SplitMix64::new(3);
        for c in [0u8, 1, 2, 97, 255] {
            let src: Vec<Gf256> = (0..1000).map(|_| Gf256(rng.next_u64() as u8)).collect();
            let mut dst: Vec<Gf256> = (0..1000).map(|_| Gf256(rng.next_u64() as u8)).collect();
            let before = dst.clone();
            mul_slice_xor(Gf256(c), &src, &mut dst);
            for i in 0..1000 {
                let expect = before[i].0 ^ mul_bitwise(c as u32, src[i].0 as u32, 8) as u8;
                assert_eq!(dst[i].0, expect, "c={c} i={i}");
            }
        }
    }

    #[test]
    fn mul_slice_xor_gf65536_matches_scalar() {
        let mut rng = SplitMix64::new(4);
        for c in [0u16, 1, 2, 0x1234, 0xFFFF] {
            let src: Vec<Gf65536> = (0..500).map(|_| Gf65536(rng.next_u64() as u16)).collect();
            let mut dst: Vec<Gf65536> = (0..500).map(|_| Gf65536(rng.next_u64() as u16)).collect();
            let before = dst.clone();
            mul_slice_xor(Gf65536(c), &src, &mut dst);
            for i in 0..500 {
                let expect = before[i].0 ^ mul_bitwise(c as u32, src[i].0 as u32, 16) as u16;
                assert_eq!(dst[i].0, expect, "c={c} i={i}");
            }
        }
    }

    #[test]
    fn mul_slice_overwrites() {
        let src = vec![Gf256(7); 64];
        let mut dst = vec![Gf256(0xAA); 64];
        mul_slice(Gf256(3), &src, &mut dst);
        let expect = Gf256(3).mul(Gf256(7));
        assert!(dst.iter().all(|&d| d == expect));
    }

    #[test]
    fn mul_slice_by_zero_and_one() {
        let src: Vec<Gf256> = (0..100).map(|i| Gf256(i as u8)).collect();
        let mut dst = vec![Gf256(0x55); 100];
        mul_slice(Gf256(0), &src, &mut dst);
        assert!(dst.iter().all(|&d| d == Gf256::ZERO));
        mul_slice(Gf256(1), &src, &mut dst);
        assert_eq!(dst, src);
    }

    #[test]
    fn xor_slice_is_involution() {
        let mut rng = SplitMix64::new(5);
        let src: Vec<Gf256> = (0..256).map(|_| Gf256(rng.next_u64() as u8)).collect();
        let orig: Vec<Gf256> = (0..256).map(|_| Gf256(rng.next_u64() as u8)).collect();
        let mut dst = orig.clone();
        xor_slice(&src, &mut dst);
        xor_slice(&src, &mut dst);
        assert_eq!(dst, orig);
    }

    #[test]
    fn byte_views_roundtrip() {
        let bytes: Vec<u8> = (0..64).collect();
        let view = bytes_as_gf256(&bytes);
        assert_eq!(view.len(), 64);
        assert_eq!(view[10], Gf256(10));
        let wide = bytes_as_gf65536(&bytes);
        assert_eq!(wide.len(), 32);
        assert_eq!(wide[0], Gf65536(u16::from_le_bytes([0, 1])));
    }

    #[test]
    fn ops_report_the_work_actually_done() {
        let src = vec![Gf256(7); 100];
        let mut dst = vec![Gf256(1); 100];
        // zero coefficient: the op skips everything and reports nothing
        assert_eq!(mul_slice_xor(Gf256(0), &src, &mut dst), GfWork::ZERO);
        // one: the XOR shortcut
        assert_eq!(mul_slice_xor(Gf256(1), &src, &mut dst), GfWork::xor(100));
        // general: one MAC pass over the payload bytes
        assert_eq!(mul_slice_xor(Gf256(5), &src, &mut dst), GfWork::mac(100));
        // GF(2^16) counts bytes, not symbols
        let src16 = vec![Gf65536(9); 50];
        let mut dst16 = vec![Gf65536(0); 50];
        assert_eq!(mul_slice_xor(Gf65536(3), &src16, &mut dst16), GfWork::mac(100));
        assert_eq!(xor_slice(&src16, &mut dst16), GfWork::xor(100));
        assert_eq!(mul_slice(Gf256(0), &src, &mut dst), GfWork::xor(100));
    }

    #[test]
    fn slice_linearity() {
        // c*(x ⊕ y) == c*x ⊕ c*y at the slice level.
        let mut rng = SplitMix64::new(6);
        let x: Vec<Gf256> = (0..333).map(|_| Gf256(rng.next_u64() as u8)).collect();
        let y: Vec<Gf256> = (0..333).map(|_| Gf256(rng.next_u64() as u8)).collect();
        let c = Gf256(0x53);
        let xy: Vec<Gf256> = x.iter().zip(&y).map(|(a, b)| a.add(*b)).collect();
        let mut lhs = vec![Gf256::ZERO; 333];
        mul_slice(c, &xy, &mut lhs);
        let mut rhs = vec![Gf256::ZERO; 333];
        mul_slice(c, &x, &mut rhs);
        mul_slice_xor(c, &y, &mut rhs);
        assert_eq!(lhs, rhs);
    }
}
