//! Dense matrices over GF(2^w): construction (identity, Cauchy), products,
//! row selection — shared by the code constructions and the census.

use super::field::GfElem;

/// Row-major dense matrix over a GF field.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Matrix<F: GfElem> {
    rows: usize,
    cols: usize,
    data: Vec<F>,
}

impl<F: GfElem> std::fmt::Debug for Matrix<F> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows {
            write!(f, "  ")?;
            for c in 0..self.cols {
                write!(f, "{:>6} ", self[(r, c)].to_u32())?;
            }
            writeln!(f)?;
        }
        write!(f, "]")
    }
}

impl<F: GfElem> Matrix<F> {
    /// All-zero matrix.
    pub fn zero(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![F::ZERO; rows * cols],
        }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zero(n, n);
        for i in 0..n {
            m[(i, i)] = F::ONE;
        }
        m
    }

    /// Build from a row-major closure.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> F) -> Self {
        let mut m = Self::zero(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m[(r, c)] = f(r, c);
            }
        }
        m
    }

    /// Build from nested vectors (rows of equal length).
    pub fn from_rows(rows: Vec<Vec<F>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Self {
            rows: r,
            cols: c,
            data: rows.into_iter().flatten().collect(),
        }
    }

    /// Cauchy matrix: `a[i][j] = 1 / (x_i + y_j)` with all x_i, y_j distinct
    /// and x_i != y_j. Any square submatrix is invertible — the classical
    /// way to build an MDS generator (the paper's CEC baseline uses Cauchy
    /// Reed-Solomon per Plank et al. [23]).
    pub fn cauchy(rows: usize, cols: usize) -> Self {
        let field_size = 1u64 << F::BITS;
        assert!(
            (rows + cols) as u64 <= field_size,
            "field too small for a {rows}x{cols} Cauchy matrix"
        );
        // x_i = i, y_j = rows + j — disjoint by construction.
        Self::from_fn(rows, cols, |i, j| {
            let x = F::from_u32(i as u32);
            let y = F::from_u32((rows + j) as u32);
            x.add(y).inv()
        })
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow one row as a slice.
    pub fn row(&self, r: usize) -> &[F] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row access.
    pub fn row_mut(&mut self, r: usize) -> &mut [F] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// New matrix keeping only `which` rows (in the given order).
    pub fn select_rows(&self, which: &[usize]) -> Self {
        let mut m = Self::zero(which.len(), self.cols);
        for (dst, &src) in which.iter().enumerate() {
            m.row_mut(dst).copy_from_slice(self.row(src));
        }
        m
    }

    /// Matrix product over the field.
    pub fn mul(&self, other: &Self) -> Self {
        assert_eq!(self.cols, other.rows, "dimension mismatch");
        let mut out = Self::zero(self.rows, other.cols);
        for i in 0..self.rows {
            for l in 0..self.cols {
                let a = self[(i, l)];
                if a == F::ZERO {
                    continue;
                }
                for j in 0..other.cols {
                    let t = a.mul(other[(l, j)]);
                    out[(i, j)] = out[(i, j)].add(t);
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn mul_vec(&self, v: &[F]) -> Vec<F> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| {
                let mut acc = F::ZERO;
                for (a, b) in self.row(i).iter().zip(v) {
                    acc = acc.add(a.mul(*b));
                }
                acc
            })
            .collect()
    }

    /// Vertical concatenation (same column count).
    pub fn vstack(&self, other: &Self) -> Self {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Self {
            rows: self.rows + other.rows,
            cols: self.cols,
            data,
        }
    }

    /// Swap two rows in place.
    pub fn swap_rows(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        let (a, b) = (a.min(b), a.max(b));
        let (head, tail) = self.data.split_at_mut(b * self.cols);
        head[a * self.cols..(a + 1) * self.cols].swap_with_slice(&mut tail[..self.cols]);
    }

    /// True if every entry is zero.
    pub fn is_zero(&self) -> bool {
        self.data.iter().all(|&x| x == F::ZERO)
    }
}

impl<F: GfElem> std::ops::Index<(usize, usize)> for Matrix<F> {
    type Output = F;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &F {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl<F: GfElem> std::ops::IndexMut<(usize, usize)> for Matrix<F> {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut F {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::field::{Gf256, Gf65536};
    use crate::gf::gauss;

    #[test]
    fn identity_is_neutral() {
        let id = Matrix::<Gf256>::identity(4);
        let m = Matrix::<Gf256>::from_fn(4, 4, |i, j| Gf256((i * 4 + j + 1) as u8));
        assert_eq!(id.mul(&m), m);
        assert_eq!(m.mul(&id), m);
    }

    #[test]
    fn cauchy_square_submatrices_invertible() {
        let c = Matrix::<Gf256>::cauchy(4, 6);
        // every single entry nonzero
        for i in 0..4 {
            for j in 0..6 {
                assert_ne!(c[(i, j)], Gf256::ZERO);
            }
        }
        // all 4x4 column selections have full rank (MDS property witness)
        let cols: Vec<usize> = (0..6).collect();
        for a in 0..6 {
            for b in (a + 1)..6 {
                let keep: Vec<usize> = cols.iter().copied().filter(|&x| x != a && x != b).collect();
                let sub = Matrix::<Gf256>::from_fn(4, 4, |i, j| c[(i, keep[j])]);
                assert_eq!(gauss::rank(&sub), 4);
            }
        }
    }

    #[test]
    fn cauchy_gf65536_smoke() {
        let c = Matrix::<Gf65536>::cauchy(5, 11);
        assert_eq!(c.rows(), 5);
        assert_eq!(gauss::rank(&c), 5);
    }

    #[test]
    #[should_panic(expected = "field too small")]
    fn cauchy_too_big_panics() {
        let _ = Matrix::<Gf256>::cauchy(200, 100);
    }

    #[test]
    fn mul_vec_matches_mul() {
        let m = Matrix::<Gf256>::from_fn(3, 5, |i, j| Gf256((7 * i + j) as u8));
        let v: Vec<Gf256> = (0..5).map(|i| Gf256(i as u8 + 1)).collect();
        let col = Matrix::from_rows(v.iter().map(|&x| vec![x]).collect());
        let prod = m.mul(&col);
        let mv = m.mul_vec(&v);
        for i in 0..3 {
            assert_eq!(prod[(i, 0)], mv[i]);
        }
    }

    #[test]
    fn select_and_stack() {
        let m = Matrix::<Gf256>::from_fn(4, 2, |i, j| Gf256((i * 2 + j) as u8));
        let s = m.select_rows(&[3, 1]);
        assert_eq!(s.row(0), m.row(3));
        assert_eq!(s.row(1), m.row(1));
        let v = m.vstack(&s);
        assert_eq!(v.rows(), 6);
        assert_eq!(v.row(4), m.row(3));
    }

    #[test]
    fn swap_rows_works() {
        let mut m = Matrix::<Gf256>::from_fn(3, 3, |i, _| Gf256(i as u8));
        m.swap_rows(0, 2);
        assert_eq!(m[(0, 0)], Gf256(2));
        assert_eq!(m[(2, 0)], Gf256(0));
    }
}
