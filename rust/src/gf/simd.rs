//! SIMD GF kernels with runtime dispatch — the hot-path backend behind
//! [`crate::gf::slice`].
//!
//! Every bulk GF op in the crate funnels through one [`Kernel`]: a scalar
//! 256-entry-table pass (always available, the PR-1..5 behavior), or a
//! vectorized split-nibble pass on x86-64 (SSSE3/AVX2 `PSHUFB`) and
//! aarch64 (NEON `TBL`). The trick is gf-complete's `SPLIT` scheme: a
//! GF(2^8) product by a fixed coefficient `c` is linear over the nibbles
//! of the source byte,
//!
//! ```text
//! c·x = lo_tbl[x & 0xF] ⊕ hi_tbl[x >> 4]
//! ```
//!
//! so two 16-entry product tables fit in vector registers and one
//! byte-shuffle instruction performs 16/32 table lookups at once. GF(2^16)
//! splits each little-endian word into four nibbles (four 16-entry `u16`
//! tables, stored as separate low/high byte planes for the shuffles) and
//! de/re-interleaves the byte pairs around the lookup.
//!
//! Dispatch rules:
//!
//! * [`Kernel::active`] picks the widest runtime-detected kernel once per
//!   process (`is_x86_feature_detected!` / NEON detection), overridable
//!   with `RAPIDRAID_FORCE_SCALAR=1` (CI runs the whole suite a second
//!   time this way) or `RAPIDRAID_KERNEL=<name>` for a specific backend.
//! * A requested kernel that is not available on the running CPU silently
//!   degrades to [`Kernel::Scalar`] — the dispatch functions re-check
//!   availability before entering any `unsafe` block, so a hand-built
//!   `Kernel` value can never execute unsupported instructions.
//! * Work accounting is *not* done here: callers
//!   ([`crate::gf::slice::SliceOps`], the native backend) report the same
//!   [`GfWork`](crate::resources::GfWork) for every kernel, so cost
//!   models, `ZeroCost` tick-identity and SimClock determinism are
//!   backend-independent by construction.
//!
//! Safety: the vector loops use unaligned loads/stores exclusively
//! (`loadu`/`storeu`, `vld1q`/`vst1q`), never read or write past
//! `min(src.len(), dst.len())` (each kernel returns how many bytes it
//! handled; the dispatcher finishes the tail with scalar nibble math), and
//! are only entered after the matching CPU feature was runtime-detected.
//! Table lookups index 16-entry arrays with 4-bit values, so no
//! out-of-bounds access is possible by construction.

use std::sync::OnceLock;

use super::field::{Gf256, Gf65536, GfElem};

// The byte views used by both the scalar GF(2^16) pass and the SIMD
// kernels assume little-endian symbol layout (as does the rest of the
// crate: `bytes_as_gf65536` transmutes network payloads in place).
#[cfg(target_endian = "big")]
compile_error!("rapidraid's GF byte views assume a little-endian target");

/// One GF slice-op backend.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable 256-entry-table passes (always available).
    Scalar,
    /// x86-64 128-bit split-nibble shuffles (`PSHUFB`).
    Ssse3,
    /// x86-64 256-bit split-nibble shuffles.
    Avx2,
    /// aarch64 128-bit split-nibble shuffles (`TBL`).
    Neon,
}

fn detect_ssse3() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("ssse3")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn detect_avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn detect_neon() -> bool {
    #[cfg(target_arch = "aarch64")]
    {
        std::arch::is_aarch64_feature_detected!("neon")
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        false
    }
}

/// Pure kernel-selection rule (extracted so tests can drive it without
/// touching process environment): forced scalar wins, then an explicitly
/// requested available kernel, then the widest detected one.
fn resolve(force_scalar: bool, requested: Option<&str>) -> Kernel {
    if force_scalar {
        return Kernel::Scalar;
    }
    if let Some(name) = requested {
        if let Some(k) = Kernel::from_name(name) {
            if k.is_available() {
                return k;
            }
        }
    }
    Kernel::detect()
}

impl Kernel {
    /// Every kernel, widest last (sweep order for benches).
    pub const ALL: [Kernel; 4] = [Kernel::Scalar, Kernel::Ssse3, Kernel::Avx2, Kernel::Neon];

    /// Stable lowercase label (also the `RAPIDRAID_KERNEL` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Ssse3 => "ssse3",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }

    /// Parse a `RAPIDRAID_KERNEL` value.
    pub fn from_name(name: &str) -> Option<Kernel> {
        Kernel::ALL.into_iter().find(|k| k.name() == name)
    }

    /// Whether this kernel can run on the current CPU.
    pub fn is_available(self) -> bool {
        match self {
            Kernel::Scalar => true,
            Kernel::Ssse3 => detect_ssse3(),
            Kernel::Avx2 => detect_avx2(),
            Kernel::Neon => detect_neon(),
        }
    }

    /// The widest kernel the running CPU supports.
    pub fn detect() -> Kernel {
        if detect_avx2() {
            Kernel::Avx2
        } else if detect_ssse3() {
            Kernel::Ssse3
        } else if detect_neon() {
            Kernel::Neon
        } else {
            Kernel::Scalar
        }
    }

    /// Every kernel available on this CPU (scalar first) — the bench
    /// sweep's backend axis.
    pub fn available_kernels() -> Vec<Kernel> {
        Kernel::ALL.into_iter().filter(|k| k.is_available()).collect()
    }

    /// The kernel the slice ops use, resolved once per process:
    /// `RAPIDRAID_FORCE_SCALAR=1` forces the fallback,
    /// `RAPIDRAID_KERNEL=<name>` requests a specific backend (ignored if
    /// unavailable), otherwise the widest detected kernel wins.
    pub fn active() -> Kernel {
        static ACTIVE: OnceLock<Kernel> = OnceLock::new();
        *ACTIVE.get_or_init(|| {
            let force = std::env::var("RAPIDRAID_FORCE_SCALAR")
                .map(|v| !v.is_empty() && v != "0")
                .unwrap_or(false);
            let requested = std::env::var("RAPIDRAID_KERNEL").ok();
            resolve(force, requested.as_deref())
        })
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------------
// Coefficient table construction
// ---------------------------------------------------------------------------

/// GF(2^8) split-nibble product tables: `lo[n] = c·n`, `hi[n] = c·(n<<4)`.
fn nib_tables8(c: u8) -> ([u8; 16], [u8; 16]) {
    let mut lo = [0u8; 16];
    let mut hi = [0u8; 16];
    if c == 0 {
        return (lo, hi);
    }
    let t = Gf256::tables();
    let lc = t.log[c as usize];
    for (n, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate().skip(1) {
        *l = t.exp[(lc + t.log[n]) as usize] as u8;
        *h = t.exp[(lc + t.log[n << 4]) as usize] as u8;
    }
    (lo, hi)
}

/// GF(2^16) split-nibble product tables: `t[i][n] = c·(n << 4i)`.
fn nib_tables16(c: u16) -> [[u16; 16]; 4] {
    let mut t = [[0u16; 16]; 4];
    if c == 0 {
        return t;
    }
    let tabs = Gf65536::tables();
    let lc = tabs.log[c as usize];
    for (i, tbl) in t.iter_mut().enumerate() {
        for (n, slot) in tbl.iter_mut().enumerate().skip(1) {
            *slot = tabs.exp[(lc + tabs.log[n << (4 * i)]) as usize] as u16;
        }
    }
    t
}

/// Split the four `u16` nibble tables into low/high byte planes — the form
/// the byte shuffles consume.
#[cfg_attr(not(any(target_arch = "x86_64", target_arch = "aarch64")), allow(dead_code))]
fn planes16(t: &[[u16; 16]; 4]) -> ([[u8; 16]; 4], [[u8; 16]; 4]) {
    let mut lo = [[0u8; 16]; 4];
    let mut hi = [[0u8; 16]; 4];
    for ((tw, tl), th) in t.iter().zip(lo.iter_mut()).zip(hi.iter_mut()) {
        for ((w, l), h) in tw.iter().zip(tl.iter_mut()).zip(th.iter_mut()) {
            *l = *w as u8;
            *h = (*w >> 8) as u8;
        }
    }
    (lo, hi)
}

/// Scalar nibble-table product for one GF(2^16) word (SIMD tail handling).
#[inline]
fn nib_mul16(t: &[[u16; 16]; 4], x: u16) -> u16 {
    t[0][(x & 0xF) as usize]
        ^ t[1][((x >> 4) & 0xF) as usize]
        ^ t[2][((x >> 8) & 0xF) as usize]
        ^ t[3][(x >> 12) as usize]
}

// ---------------------------------------------------------------------------
// Scalar kernels (the always-available fallback)
// ---------------------------------------------------------------------------

mod scalar {
    use crate::gf::field::{Gf256, Gf65536, GfElem};

    /// 256-entry product table for a GF(2^8) coefficient.
    fn table256(c: u8) -> [u8; 256] {
        let mut t = [0u8; 256];
        if c == 0 {
            return t;
        }
        let tabs = Gf256::tables();
        let lc = tabs.log[c as usize];
        for (x, slot) in t.iter_mut().enumerate().skip(1) {
            *slot = tabs.exp[(lc + tabs.log[x]) as usize] as u8;
        }
        t
    }

    /// Two 256-entry split-byte tables for a GF(2^16) coefficient:
    /// `lo[b] = c·b`, `hi[b] = c·(b << 8)`.
    fn tables65536(c: u16) -> ([u16; 256], [u16; 256]) {
        let mut lo = [0u16; 256];
        let mut hi = [0u16; 256];
        if c == 0 {
            return (lo, hi);
        }
        let tabs = Gf65536::tables();
        let lc = tabs.log[c as usize];
        for b in 1usize..256 {
            lo[b] = tabs.exp[(lc + tabs.log[b]) as usize] as u16;
            hi[b] = tabs.exp[(lc + tabs.log[b << 8]) as usize] as u16;
        }
        (lo, hi)
    }

    /// `dst ^= c·src` (XOR=true) / `dst = c·src` (XOR=false) over GF(2^8).
    pub fn mul8<const XOR: bool>(c: u8, src: &[u8], dst: &mut [u8]) {
        let t = table256(c);
        // 8-way unroll: keeps the table-lookup pipeline full on one core.
        let n = src.len();
        let chunks = n / 8 * 8;
        for i in (0..chunks).step_by(8) {
            if XOR {
                dst[i] ^= t[src[i] as usize];
                dst[i + 1] ^= t[src[i + 1] as usize];
                dst[i + 2] ^= t[src[i + 2] as usize];
                dst[i + 3] ^= t[src[i + 3] as usize];
                dst[i + 4] ^= t[src[i + 4] as usize];
                dst[i + 5] ^= t[src[i + 5] as usize];
                dst[i + 6] ^= t[src[i + 6] as usize];
                dst[i + 7] ^= t[src[i + 7] as usize];
            } else {
                dst[i] = t[src[i] as usize];
                dst[i + 1] = t[src[i + 1] as usize];
                dst[i + 2] = t[src[i + 2] as usize];
                dst[i + 3] = t[src[i + 3] as usize];
                dst[i + 4] = t[src[i + 4] as usize];
                dst[i + 5] = t[src[i + 5] as usize];
                dst[i + 6] = t[src[i + 6] as usize];
                dst[i + 7] = t[src[i + 7] as usize];
            }
        }
        for i in chunks..n {
            if XOR {
                dst[i] ^= t[src[i] as usize];
            } else {
                dst[i] = t[src[i] as usize];
            }
        }
    }

    /// `dst ^= c·src` / `dst = c·src` over GF(2^16) on little-endian byte
    /// pairs (length must be even; the dispatcher checks).
    pub fn mul16<const XOR: bool>(c: u16, src: &[u8], dst: &mut [u8]) {
        let (lo, hi) = tables65536(c);
        for (d, s) in dst.chunks_exact_mut(2).zip(src.chunks_exact(2)) {
            let p = lo[s[0] as usize] ^ hi[s[1] as usize];
            let v = if XOR {
                u16::from_le_bytes([d[0], d[1]]) ^ p
            } else {
                p
            };
            d.copy_from_slice(&v.to_le_bytes());
        }
    }

    /// `dst ^= src`, 8 bytes per step via `u64` words (any alignment —
    /// the words are assembled with `from_ne_bytes`).
    pub fn xor_wide(src: &[u8], dst: &mut [u8]) {
        for (d, s) in dst.chunks_exact_mut(8).zip(src.chunks_exact(8)) {
            let dv = u64::from_ne_bytes(<[u8; 8]>::try_from(&d[..]).unwrap());
            let sv = u64::from_ne_bytes(<[u8; 8]>::try_from(s).unwrap());
            d.copy_from_slice(&(dv ^ sv).to_ne_bytes());
        }
        let n = src.len();
        let done = n / 8 * 8;
        for i in done..n {
            dst[i] ^= src[i];
        }
    }
}

// ---------------------------------------------------------------------------
// x86-64 kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// GF(2^8) split-nibble pass, 16 bytes per step. Returns bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified SSSE3 support.
    #[target_feature(enable = "ssse3")]
    pub unsafe fn mul8_ssse3<const XOR: bool>(
        tlo: &[u8; 16],
        thi: &[u8; 16],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let lo = _mm_loadu_si128(tlo.as_ptr() as *const __m128i);
        let hi = _mm_loadu_si128(thi.as_ptr() as *const __m128i);
        let nib = _mm_set1_epi8(0x0F);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 16 <= n {
            let s = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let ln = _mm_and_si128(s, nib);
            let hn = _mm_and_si128(_mm_srli_epi64::<4>(s), nib);
            let mut p = _mm_xor_si128(_mm_shuffle_epi8(lo, ln), _mm_shuffle_epi8(hi, hn));
            if XOR {
                p = _mm_xor_si128(p, _mm_loadu_si128(dst.as_ptr().add(i) as *const __m128i));
            }
            _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, p);
            i += 16;
        }
        i
    }

    /// GF(2^8) split-nibble pass, 32 bytes per step. Returns bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul8_avx2<const XOR: bool>(
        tlo: &[u8; 16],
        thi: &[u8; 16],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let lo = _mm256_broadcastsi128_si256(_mm_loadu_si128(tlo.as_ptr() as *const __m128i));
        let hi = _mm256_broadcastsi128_si256(_mm_loadu_si128(thi.as_ptr() as *const __m128i));
        let nib = _mm256_set1_epi8(0x0F);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let ln = _mm256_and_si256(s, nib);
            let hn = _mm256_and_si256(_mm256_srli_epi64::<4>(s), nib);
            let mut p =
                _mm256_xor_si256(_mm256_shuffle_epi8(lo, ln), _mm256_shuffle_epi8(hi, hn));
            if XOR {
                p = _mm256_xor_si256(
                    p,
                    _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i),
                );
            }
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, p);
            i += 32;
        }
        i
    }

    /// GF(2^16) four-nibble pass over little-endian byte pairs, 16 words
    /// (32 bytes) per step: deinterleave the lo/hi source bytes with
    /// pack/shift, shuffle the four byte-plane tables, reinterleave with
    /// unpack. Returns bytes done (a multiple of 32).
    ///
    /// # Safety
    /// Caller must have runtime-verified SSSE3 support.
    #[target_feature(enable = "ssse3")]
    pub unsafe fn mul16_ssse3<const XOR: bool>(
        plo: &[[u8; 16]; 4],
        phi: &[[u8; 16]; 4],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let t: [__m128i; 4] = [
            _mm_loadu_si128(plo[0].as_ptr() as *const __m128i),
            _mm_loadu_si128(plo[1].as_ptr() as *const __m128i),
            _mm_loadu_si128(plo[2].as_ptr() as *const __m128i),
            _mm_loadu_si128(plo[3].as_ptr() as *const __m128i),
        ];
        let u: [__m128i; 4] = [
            _mm_loadu_si128(phi[0].as_ptr() as *const __m128i),
            _mm_loadu_si128(phi[1].as_ptr() as *const __m128i),
            _mm_loadu_si128(phi[2].as_ptr() as *const __m128i),
            _mm_loadu_si128(phi[3].as_ptr() as *const __m128i),
        ];
        let nib = _mm_set1_epi8(0x0F);
        let bytemask = _mm_set1_epi16(0x00FF);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let v0 = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let v1 = _mm_loadu_si128(src.as_ptr().add(i + 16) as *const __m128i);
            // deinterleave: lo = low bytes of the 16 words, hi = high bytes
            let lo = _mm_packus_epi16(_mm_and_si128(v0, bytemask), _mm_and_si128(v1, bytemask));
            let hi = _mm_packus_epi16(_mm_srli_epi16::<8>(v0), _mm_srli_epi16::<8>(v1));
            let n0 = _mm_and_si128(lo, nib);
            let n1 = _mm_and_si128(_mm_srli_epi64::<4>(lo), nib);
            let n2 = _mm_and_si128(hi, nib);
            let n3 = _mm_and_si128(_mm_srli_epi64::<4>(hi), nib);
            let rlo = _mm_xor_si128(
                _mm_xor_si128(_mm_shuffle_epi8(t[0], n0), _mm_shuffle_epi8(t[1], n1)),
                _mm_xor_si128(_mm_shuffle_epi8(t[2], n2), _mm_shuffle_epi8(t[3], n3)),
            );
            let rhi = _mm_xor_si128(
                _mm_xor_si128(_mm_shuffle_epi8(u[0], n0), _mm_shuffle_epi8(u[1], n1)),
                _mm_xor_si128(_mm_shuffle_epi8(u[2], n2), _mm_shuffle_epi8(u[3], n3)),
            );
            // reinterleave the product byte planes back into words
            let mut p0 = _mm_unpacklo_epi8(rlo, rhi);
            let mut p1 = _mm_unpackhi_epi8(rlo, rhi);
            if XOR {
                p0 = _mm_xor_si128(p0, _mm_loadu_si128(dst.as_ptr().add(i) as *const __m128i));
                p1 = _mm_xor_si128(
                    p1,
                    _mm_loadu_si128(dst.as_ptr().add(i + 16) as *const __m128i),
                );
            }
            _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, p0);
            _mm_storeu_si128(dst.as_mut_ptr().add(i + 16) as *mut __m128i, p1);
            i += 32;
        }
        i
    }

    /// GF(2^16) four-nibble pass, 32 words (64 bytes) per step. The
    /// pack/unpack pairs operate per 128-bit lane, and the composition
    /// pack → shuffle → unpack is lane-consistent, so the interleaved
    /// word layout round-trips exactly as in the SSE version. Returns
    /// bytes done (a multiple of 64).
    ///
    /// # Safety
    /// Caller must have runtime-verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul16_avx2<const XOR: bool>(
        plo: &[[u8; 16]; 4],
        phi: &[[u8; 16]; 4],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let t: [__m256i; 4] = [
            _mm256_broadcastsi128_si256(_mm_loadu_si128(plo[0].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(plo[1].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(plo[2].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(plo[3].as_ptr() as *const __m128i)),
        ];
        let u: [__m256i; 4] = [
            _mm256_broadcastsi128_si256(_mm_loadu_si128(phi[0].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(phi[1].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(phi[2].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(phi[3].as_ptr() as *const __m128i)),
        ];
        let nib = _mm256_set1_epi8(0x0F);
        let bytemask = _mm256_set1_epi16(0x00FF);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 64 <= n {
            let v0 = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let v1 = _mm256_loadu_si256(src.as_ptr().add(i + 32) as *const __m256i);
            let lo = _mm256_packus_epi16(
                _mm256_and_si256(v0, bytemask),
                _mm256_and_si256(v1, bytemask),
            );
            let hi = _mm256_packus_epi16(_mm256_srli_epi16::<8>(v0), _mm256_srli_epi16::<8>(v1));
            let n0 = _mm256_and_si256(lo, nib);
            let n1 = _mm256_and_si256(_mm256_srli_epi64::<4>(lo), nib);
            let n2 = _mm256_and_si256(hi, nib);
            let n3 = _mm256_and_si256(_mm256_srli_epi64::<4>(hi), nib);
            let rlo = _mm256_xor_si256(
                _mm256_xor_si256(_mm256_shuffle_epi8(t[0], n0), _mm256_shuffle_epi8(t[1], n1)),
                _mm256_xor_si256(_mm256_shuffle_epi8(t[2], n2), _mm256_shuffle_epi8(t[3], n3)),
            );
            let rhi = _mm256_xor_si256(
                _mm256_xor_si256(_mm256_shuffle_epi8(u[0], n0), _mm256_shuffle_epi8(u[1], n1)),
                _mm256_xor_si256(_mm256_shuffle_epi8(u[2], n2), _mm256_shuffle_epi8(u[3], n3)),
            );
            let mut p0 = _mm256_unpacklo_epi8(rlo, rhi);
            let mut p1 = _mm256_unpackhi_epi8(rlo, rhi);
            if XOR {
                p0 = _mm256_xor_si256(
                    p0,
                    _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i),
                );
                p1 = _mm256_xor_si256(
                    p1,
                    _mm256_loadu_si256(dst.as_ptr().add(i + 32) as *const __m256i),
                );
            }
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, p0);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i + 32) as *mut __m256i, p1);
            i += 64;
        }
        i
    }

    /// `dst ^= src`, 16 bytes per step (SSE2 is x86-64 baseline). Returns
    /// bytes done.
    ///
    /// # Safety
    /// `src`/`dst` must be valid for the lengths given (plain slices are).
    pub unsafe fn xor_sse2(src: &[u8], dst: &mut [u8]) -> usize {
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 16 <= n {
            let s = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let d = _mm_loadu_si128(dst.as_ptr().add(i) as *const __m128i);
            _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, _mm_xor_si128(d, s));
            i += 16;
        }
        i
    }

    /// `dst ^= src`, 32 bytes per step. Returns bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_avx2(src: &[u8], dst: &mut [u8]) -> usize {
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let d = _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(
                dst.as_mut_ptr().add(i) as *mut __m256i,
                _mm256_xor_si256(d, s),
            );
            i += 32;
        }
        i
    }
}

// ---------------------------------------------------------------------------
// aarch64 kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// GF(2^8) split-nibble pass (`TBL`), 16 bytes per step. Returns
    /// bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified NEON support.
    pub unsafe fn mul8_neon<const XOR: bool>(
        tlo: &[u8; 16],
        thi: &[u8; 16],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let lo = vld1q_u8(tlo.as_ptr());
        let hi = vld1q_u8(thi.as_ptr());
        let nib = vdupq_n_u8(0x0F);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 16 <= n {
            let s = vld1q_u8(src.as_ptr().add(i));
            let mut p = veorq_u8(
                vqtbl1q_u8(lo, vandq_u8(s, nib)),
                vqtbl1q_u8(hi, vshrq_n_u8::<4>(s)),
            );
            if XOR {
                p = veorq_u8(p, vld1q_u8(dst.as_ptr().add(i)));
            }
            vst1q_u8(dst.as_mut_ptr().add(i), p);
            i += 16;
        }
        i
    }

    /// GF(2^16) four-nibble pass over little-endian byte pairs, 16 words
    /// (32 bytes) per step: `UZP` deinterleaves the lo/hi source bytes,
    /// `TBL` looks up the four byte-plane tables, `ZIP` reinterleaves.
    /// Returns bytes done (a multiple of 32).
    ///
    /// # Safety
    /// Caller must have runtime-verified NEON support.
    pub unsafe fn mul16_neon<const XOR: bool>(
        plo: &[[u8; 16]; 4],
        phi: &[[u8; 16]; 4],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let t = [
            vld1q_u8(plo[0].as_ptr()),
            vld1q_u8(plo[1].as_ptr()),
            vld1q_u8(plo[2].as_ptr()),
            vld1q_u8(plo[3].as_ptr()),
        ];
        let u = [
            vld1q_u8(phi[0].as_ptr()),
            vld1q_u8(phi[1].as_ptr()),
            vld1q_u8(phi[2].as_ptr()),
            vld1q_u8(phi[3].as_ptr()),
        ];
        let nib = vdupq_n_u8(0x0F);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let v0 = vld1q_u8(src.as_ptr().add(i));
            let v1 = vld1q_u8(src.as_ptr().add(i + 16));
            let lo = vuzp1q_u8(v0, v1); // low bytes of the 16 words
            let hi = vuzp2q_u8(v0, v1); // high bytes
            let n0 = vandq_u8(lo, nib);
            let n1 = vshrq_n_u8::<4>(lo);
            let n2 = vandq_u8(hi, nib);
            let n3 = vshrq_n_u8::<4>(hi);
            let rlo = veorq_u8(
                veorq_u8(vqtbl1q_u8(t[0], n0), vqtbl1q_u8(t[1], n1)),
                veorq_u8(vqtbl1q_u8(t[2], n2), vqtbl1q_u8(t[3], n3)),
            );
            let rhi = veorq_u8(
                veorq_u8(vqtbl1q_u8(u[0], n0), vqtbl1q_u8(u[1], n1)),
                veorq_u8(vqtbl1q_u8(u[2], n2), vqtbl1q_u8(u[3], n3)),
            );
            let mut p0 = vzip1q_u8(rlo, rhi);
            let mut p1 = vzip2q_u8(rlo, rhi);
            if XOR {
                p0 = veorq_u8(p0, vld1q_u8(dst.as_ptr().add(i)));
                p1 = veorq_u8(p1, vld1q_u8(dst.as_ptr().add(i + 16)));
            }
            vst1q_u8(dst.as_mut_ptr().add(i), p0);
            vst1q_u8(dst.as_mut_ptr().add(i + 16), p1);
            i += 32;
        }
        i
    }

    /// `dst ^= src`, 16 bytes per step. Returns bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified NEON support.
    pub unsafe fn xor_neon(src: &[u8], dst: &mut [u8]) -> usize {
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 16 <= n {
            let s = vld1q_u8(src.as_ptr().add(i));
            let d = vld1q_u8(dst.as_ptr().add(i));
            vst1q_u8(dst.as_mut_ptr().add(i), veorq_u8(d, s));
            i += 16;
        }
        i
    }
}

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

/// Downgrade to scalar when the requested kernel can't run here — the
/// safety gate in front of every `unsafe` feature block.
#[inline]
fn usable(k: Kernel) -> Kernel {
    if k.is_available() {
        k
    } else {
        Kernel::Scalar
    }
}

fn mul8_dispatch<const XOR: bool>(k: Kernel, c: u8, src: &[u8], dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    let k = usable(k);
    if k == Kernel::Scalar {
        scalar::mul8::<XOR>(c, src, dst);
        return;
    }
    let (tlo, thi) = nib_tables8(c);
    let done = match k {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `usable` verified the feature at runtime.
        Kernel::Ssse3 => unsafe { x86::mul8_ssse3::<XOR>(&tlo, &thi, src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Kernel::Avx2 => unsafe { x86::mul8_avx2::<XOR>(&tlo, &thi, src, dst) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Kernel::Neon => unsafe { neon::mul8_neon::<XOR>(&tlo, &thi, src, dst) },
        _ => 0,
    };
    for i in done..src.len() {
        let s = src[i];
        let p = tlo[(s & 0x0F) as usize] ^ thi[(s >> 4) as usize];
        if XOR {
            dst[i] ^= p;
        } else {
            dst[i] = p;
        }
    }
}

fn mul16_dispatch<const XOR: bool>(k: Kernel, c: u16, src: &[u8], dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    assert_eq!(src.len() % 2, 0, "GF(2^16) payload must have even length");
    let k = usable(k);
    if k == Kernel::Scalar {
        scalar::mul16::<XOR>(c, src, dst);
        return;
    }
    let t = nib_tables16(c);
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    let (plo, phi) = planes16(&t);
    let done = match k {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `usable` verified the feature at runtime.
        Kernel::Ssse3 => unsafe { x86::mul16_ssse3::<XOR>(&plo, &phi, src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Kernel::Avx2 => unsafe { x86::mul16_avx2::<XOR>(&plo, &phi, src, dst) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Kernel::Neon => unsafe { neon::mul16_neon::<XOR>(&plo, &phi, src, dst) },
        _ => 0,
    };
    let n = src.len();
    let mut i = done;
    while i < n {
        let p = nib_mul16(&t, u16::from_le_bytes([src[i], src[i + 1]]));
        let v = if XOR {
            u16::from_le_bytes([dst[i], dst[i + 1]]) ^ p
        } else {
            p
        };
        dst[i..i + 2].copy_from_slice(&v.to_le_bytes());
        i += 2;
    }
}

/// `dst[i] ^= c·src[i]` over GF(2^8) byte slices on the given kernel.
/// Handles every coefficient (0 and 1 included) — the slice layer
/// shortcuts them earlier only for work accounting and speed.
pub fn mul_xor8(k: Kernel, c: u8, src: &[u8], dst: &mut [u8]) {
    mul8_dispatch::<true>(k, c, src, dst);
}

/// `dst[i] = c·src[i]` over GF(2^8) byte slices on the given kernel.
pub fn mul8(k: Kernel, c: u8, src: &[u8], dst: &mut [u8]) {
    mul8_dispatch::<false>(k, c, src, dst);
}

/// `dst[i] ^= c·src[i]` over GF(2^16) little-endian byte pairs (length
/// must be even) on the given kernel. Works on any byte alignment.
pub fn mul_xor16(k: Kernel, c: u16, src: &[u8], dst: &mut [u8]) {
    mul16_dispatch::<true>(k, c, src, dst);
}

/// `dst[i] = c·src[i]` over GF(2^16) little-endian byte pairs on the
/// given kernel.
pub fn mul16(k: Kernel, c: u16, src: &[u8], dst: &mut [u8]) {
    mul16_dispatch::<false>(k, c, src, dst);
}

/// `dst ^= src` on the given kernel (u64 words on scalar, vector XOR on
/// the SIMD kernels).
pub fn xor_bytes(k: Kernel, src: &[u8], dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    let k = usable(k);
    let done = match k {
        Kernel::Scalar => {
            scalar::xor_wide(src, dst);
            return;
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: plain slices; SSE2 is x86-64 baseline.
        Kernel::Ssse3 => unsafe { x86::xor_sse2(src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `usable` verified AVX2 at runtime.
        Kernel::Avx2 => unsafe { x86::xor_avx2(src, dst) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `usable` verified NEON at runtime.
        Kernel::Neon => unsafe { neon::xor_neon(src, dst) },
        _ => 0,
    };
    for i in done..src.len() {
        dst[i] ^= src[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::tables::mul_bitwise;
    use crate::util::rng::SplitMix64;

    #[test]
    fn kernel_names_roundtrip() {
        for k in Kernel::ALL {
            assert_eq!(Kernel::from_name(k.name()), Some(k));
            assert_eq!(format!("{k}"), k.name());
        }
        assert_eq!(Kernel::from_name("sse9"), None);
    }

    #[test]
    fn resolve_priorities() {
        // forced scalar beats everything
        assert_eq!(resolve(true, Some("avx2")), Kernel::Scalar);
        // an explicit available kernel wins over detection
        assert_eq!(resolve(false, Some("scalar")), Kernel::Scalar);
        // unknown / unavailable requests fall back to detection
        assert_eq!(resolve(false, Some("nonsense")), Kernel::detect());
        assert_eq!(resolve(false, None), Kernel::detect());
        for k in Kernel::available_kernels() {
            assert_eq!(resolve(false, Some(k.name())), k);
        }
    }

    #[test]
    fn detected_kernels_are_available_and_include_scalar() {
        let ks = Kernel::available_kernels();
        assert!(ks.contains(&Kernel::Scalar));
        assert!(ks.iter().all(|k| k.is_available()));
        assert!(Kernel::detect().is_available());
        assert!(Kernel::active().is_available());
    }

    /// Lengths that cover empty, sub-vector, exact-vector and straddling
    /// tails for every vector width in play (16/32/64 bytes).
    const LENS: [usize; 14] = [0, 1, 2, 3, 8, 15, 16, 17, 31, 32, 33, 63, 64, 257];

    #[test]
    fn mul_xor8_matches_bitwise_on_every_kernel() {
        let mut rng = SplitMix64::new(11);
        let base_src: Vec<u8> = (0..600).map(|_| rng.next_u64() as u8).collect();
        let base_dst: Vec<u8> = (0..600).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for c in [0u8, 1, 2, 3, 0x53, 0x8E, 255] {
                for len in LENS {
                    for off in 0..3usize {
                        let src = &base_src[off..off + len];
                        let mut dst = base_dst[off..off + len].to_vec();
                        mul_xor8(k, c, src, &mut dst);
                        for i in 0..len {
                            let expect = base_dst[off + i]
                                ^ mul_bitwise(c as u32, src[i] as u32, 8) as u8;
                            assert_eq!(dst[i], expect, "k={k} c={c} len={len} off={off} i={i}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mul8_overwrite_matches_bitwise_on_every_kernel() {
        let mut rng = SplitMix64::new(12);
        let src: Vec<u8> = (0..300).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for c in [0u8, 1, 7, 200] {
                let mut dst = vec![0xAAu8; src.len()];
                mul8(k, c, &src, &mut dst);
                for i in 0..src.len() {
                    assert_eq!(
                        dst[i] as u32,
                        mul_bitwise(c as u32, src[i] as u32, 8),
                        "k={k} c={c} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn mul_xor16_matches_bitwise_on_every_kernel() {
        let mut rng = SplitMix64::new(13);
        let base_src: Vec<u8> = (0..800).map(|_| rng.next_u64() as u8).collect();
        let base_dst: Vec<u8> = (0..800).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for c in [0u16, 1, 2, 0x1234, 0x8001, 0xFFFF] {
                for len in LENS.map(|l| l / 2 * 2) {
                    // odd byte offsets exercise unaligned vector loads
                    for off in [0usize, 1, 2, 3] {
                        let src = &base_src[off..off + len];
                        let mut dst = base_dst[off..off + len].to_vec();
                        mul_xor16(k, c, src, &mut dst);
                        let mut i = 0;
                        while i < len {
                            let x = u16::from_le_bytes([src[i], src[i + 1]]);
                            let d0 = u16::from_le_bytes([base_dst[off + i], base_dst[off + i + 1]]);
                            let expect = d0 ^ mul_bitwise(c as u32, x as u32, 16) as u16;
                            let got = u16::from_le_bytes([dst[i], dst[i + 1]]);
                            assert_eq!(got, expect, "k={k} c={c:#x} len={len} off={off} i={i}");
                            i += 2;
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mul16_overwrite_matches_bitwise_on_every_kernel() {
        let mut rng = SplitMix64::new(14);
        let src: Vec<u8> = (0..400).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for c in [0u16, 1, 9, 0xBEEF] {
                let mut dst = vec![0x55u8; src.len()];
                mul16(k, c, &src, &mut dst);
                let mut i = 0;
                while i < src.len() {
                    let x = u16::from_le_bytes([src[i], src[i + 1]]);
                    let got = u16::from_le_bytes([dst[i], dst[i + 1]]);
                    assert_eq!(got as u32, mul_bitwise(c as u32, x as u32, 16), "k={k} c={c:#x} i={i}");
                    i += 2;
                }
            }
        }
    }

    #[test]
    fn xor_bytes_matches_on_every_kernel() {
        let mut rng = SplitMix64::new(15);
        let src: Vec<u8> = (0..500).map(|_| rng.next_u64() as u8).collect();
        let orig: Vec<u8> = (0..500).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for len in LENS {
                for off in 0..2usize {
                    let mut dst = orig[off..off + len].to_vec();
                    xor_bytes(k, &src[off..off + len], &mut dst);
                    for i in 0..len {
                        assert_eq!(dst[i], orig[off + i] ^ src[off + i], "k={k} len={len} i={i}");
                    }
                }
            }
        }
    }

    #[test]
    fn unavailable_kernel_degrades_to_scalar() {
        // A kernel foreign to this arch must still produce correct output
        // (the dispatcher downgrades instead of entering unsafe blocks).
        let foreign = if cfg!(target_arch = "x86_64") {
            Kernel::Neon
        } else {
            Kernel::Avx2
        };
        if foreign.is_available() {
            return; // nothing to test on this host
        }
        let src = vec![7u8; 100];
        let mut dst = vec![1u8; 100];
        mul_xor8(foreign, 5, &src, &mut dst);
        let expect = 1 ^ mul_bitwise(5, 7, 8) as u8;
        assert!(dst.iter().all(|&b| b == expect));
    }

    #[test]
    fn nibble_tables_compose_the_product() {
        let (lo, hi) = nib_tables8(0x53);
        for x in 0u32..256 {
            let got = lo[(x & 0xF) as usize] ^ hi[(x >> 4) as usize];
            assert_eq!(got as u32, mul_bitwise(0x53, x, 8), "x={x}");
        }
        let t = nib_tables16(0x1234);
        for x in [0u32, 1, 0xFF, 0x100, 0xABCD, 0xFFFF] {
            assert_eq!(nib_mul16(&t, x as u16) as u32, mul_bitwise(0x1234, x, 16), "x={x}");
        }
    }
}
