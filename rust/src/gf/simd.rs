//! SIMD GF kernels with runtime dispatch — the hot-path backend behind
//! [`crate::gf::slice`].
//!
//! Every bulk GF op in the crate funnels through one [`Kernel`]: a scalar
//! 256-entry-table pass (always available, the PR-1..5 behavior), or a
//! vectorized split-nibble pass on x86-64 (SSSE3/AVX2 `PSHUFB`) and
//! aarch64 (NEON `TBL`). The trick is gf-complete's `SPLIT` scheme: a
//! GF(2^8) product by a fixed coefficient `c` is linear over the nibbles
//! of the source byte,
//!
//! ```text
//! c·x = lo_tbl[x & 0xF] ⊕ hi_tbl[x >> 4]
//! ```
//!
//! so two 16-entry product tables fit in vector registers and one
//! byte-shuffle instruction performs 16/32 table lookups at once. GF(2^16)
//! splits each little-endian word into four nibbles (four 16-entry `u16`
//! tables, stored as separate low/high byte planes for the shuffles) and
//! de/re-interleaves the byte pairs around the lookup.
//!
//! On CPUs with GFNI (Ice Lake and newer) there is a still-wider tier:
//! `GF2P8AFFINEQB` applies an arbitrary 8×8 GF(2) bit-matrix to every
//! byte of a vector, and multiplication by a fixed coefficient is exactly
//! such a linear map — one instruction replaces both nibble shuffles (and
//! a 2×2 block of four matrices handles GF(2^16) on the deinterleaved
//! byte planes). See [`affine_matrix8`]/[`affine_matrices16`].
//!
//! Beyond the single-coefficient ops, two *multi-output* entry points
//! exist so the hottest loops read their source bytes once:
//!
//! * [`mul2_xor8`]/[`mul2_xor16`] — the fused RapidRAID relay stage
//!   `x ^= p·s, c ^= q·s`: one source load feeds both coefficient
//!   lookups, with both accumulators updated in registers.
//! * [`gemm_rows8`]/[`gemm_rows16`] — row-batched GEMM: output rows are
//!   processed in pairs per L1-blocked source pass via the fused
//!   kernels, halving source reads vs one pass per matrix cell.
//!
//! Dispatch rules:
//!
//! * [`Kernel::active`] picks the widest runtime-detected kernel once per
//!   process (`is_x86_feature_detected!` / NEON detection), overridable
//!   with `RAPIDRAID_FORCE_SCALAR=1` or `RAPIDRAID_KERNEL=<name>` for a
//!   specific backend (CI's tier-1 job is a forced-kernel matrix over
//!   scalar/ssse3/avx2 plus a detection-default leg).
//! * A requested kernel that is not available on the running CPU silently
//!   degrades to [`Kernel::Scalar`] — the dispatch functions re-check
//!   availability before entering any `unsafe` block, so a hand-built
//!   `Kernel` value can never execute unsupported instructions.
//! * Work accounting is *not* done here: callers
//!   ([`crate::gf::slice::SliceOps`], the native backend) report the same
//!   [`GfWork`](crate::resources::GfWork) for every kernel, so cost
//!   models, `ZeroCost` tick-identity and SimClock determinism are
//!   backend-independent by construction.
//!
//! Safety: the vector loops use unaligned loads/stores exclusively
//! (`loadu`/`storeu`, `vld1q`/`vst1q`), never read or write past
//! `min(src.len(), dst.len())` (each kernel returns how many bytes it
//! handled; the dispatcher finishes the tail with scalar nibble math), and
//! are only entered after the matching CPU feature was runtime-detected.
//! Table lookups index 16-entry arrays with 4-bit values, so no
//! out-of-bounds access is possible by construction.

use std::sync::OnceLock;

use super::field::{Gf256, Gf65536, GfElem};
use super::tables::mul_bitwise;

// The byte views used by both the scalar GF(2^16) pass and the SIMD
// kernels assume little-endian symbol layout (as does the rest of the
// crate: `bytes_as_gf65536` transmutes network payloads in place).
#[cfg(target_endian = "big")]
compile_error!("rapidraid's GF byte views assume a little-endian target");

/// One GF slice-op backend.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable 256-entry-table passes (always available).
    Scalar,
    /// x86-64 128-bit split-nibble shuffles (`PSHUFB`).
    Ssse3,
    /// x86-64 256-bit split-nibble shuffles.
    Avx2,
    /// aarch64 128-bit split-nibble shuffles (`TBL`).
    Neon,
    /// x86-64 256-bit Galois-field affine instructions (`GF2P8AFFINEQB`)
    /// — coefficients encoded as 8×8 GF(2) bit-matrices, one instruction
    /// per 32 products. Requires GFNI *and* AVX2 (every GFNI CPU has
    /// both).
    Gfni,
}

fn detect_ssse3() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("ssse3")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn detect_avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn detect_neon() -> bool {
    #[cfg(target_arch = "aarch64")]
    {
        std::arch::is_aarch64_feature_detected!("neon")
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        false
    }
}

fn detect_gfni() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        // The tier uses the 256-bit VEX form exclusively, so it needs
        // AVX2 alongside GFNI (true of every GFNI part shipped to date).
        std::is_x86_feature_detected!("gfni") && std::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Pure kernel-selection rule (extracted so tests can drive it without
/// touching process environment): forced scalar wins, then an explicitly
/// requested available kernel, then the widest detected one.
fn resolve(force_scalar: bool, requested: Option<&str>) -> Kernel {
    if force_scalar {
        return Kernel::Scalar;
    }
    if let Some(name) = requested {
        if let Some(k) = Kernel::from_name(name) {
            if k.is_available() {
                return k;
            }
        }
    }
    Kernel::detect()
}

impl Kernel {
    /// Every kernel, widest last (sweep order for benches).
    pub const ALL: [Kernel; 5] = [
        Kernel::Scalar,
        Kernel::Ssse3,
        Kernel::Avx2,
        Kernel::Neon,
        Kernel::Gfni,
    ];

    /// Stable lowercase label (also the `RAPIDRAID_KERNEL` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Ssse3 => "ssse3",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
            Kernel::Gfni => "gfni",
        }
    }

    /// Parse a `RAPIDRAID_KERNEL` value.
    pub fn from_name(name: &str) -> Option<Kernel> {
        Kernel::ALL.into_iter().find(|k| k.name() == name)
    }

    /// Whether this kernel can run on the current CPU.
    pub fn is_available(self) -> bool {
        match self {
            Kernel::Scalar => true,
            Kernel::Ssse3 => detect_ssse3(),
            Kernel::Avx2 => detect_avx2(),
            Kernel::Neon => detect_neon(),
            Kernel::Gfni => detect_gfni(),
        }
    }

    /// The widest kernel the running CPU supports.
    pub fn detect() -> Kernel {
        if detect_gfni() {
            Kernel::Gfni
        } else if detect_avx2() {
            Kernel::Avx2
        } else if detect_ssse3() {
            Kernel::Ssse3
        } else if detect_neon() {
            Kernel::Neon
        } else {
            Kernel::Scalar
        }
    }

    /// Every kernel available on this CPU (scalar first) — the bench
    /// sweep's backend axis.
    pub fn available_kernels() -> Vec<Kernel> {
        Kernel::ALL.into_iter().filter(|k| k.is_available()).collect()
    }

    /// The kernel the slice ops use, resolved once per process:
    /// `RAPIDRAID_FORCE_SCALAR=1` forces the fallback,
    /// `RAPIDRAID_KERNEL=<name>` requests a specific backend (ignored if
    /// unavailable), otherwise the widest detected kernel wins.
    pub fn active() -> Kernel {
        static ACTIVE: OnceLock<Kernel> = OnceLock::new();
        *ACTIVE.get_or_init(|| {
            let force = std::env::var("RAPIDRAID_FORCE_SCALAR")
                .map(|v| !v.is_empty() && v != "0")
                .unwrap_or(false);
            let requested = std::env::var("RAPIDRAID_KERNEL").ok();
            resolve(force, requested.as_deref())
        })
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------------
// Coefficient table construction
// ---------------------------------------------------------------------------

/// GF(2^8) split-nibble product tables: `lo[n] = c·n`, `hi[n] = c·(n<<4)`.
fn nib_tables8(c: u8) -> ([u8; 16], [u8; 16]) {
    let mut lo = [0u8; 16];
    let mut hi = [0u8; 16];
    if c == 0 {
        return (lo, hi);
    }
    let t = Gf256::tables();
    let lc = t.log[c as usize];
    for (n, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate().skip(1) {
        *l = t.exp[(lc + t.log[n]) as usize] as u8;
        *h = t.exp[(lc + t.log[n << 4]) as usize] as u8;
    }
    (lo, hi)
}

/// GF(2^16) split-nibble product tables: `t[i][n] = c·(n << 4i)`.
fn nib_tables16(c: u16) -> [[u16; 16]; 4] {
    let mut t = [[0u16; 16]; 4];
    if c == 0 {
        return t;
    }
    let tabs = Gf65536::tables();
    let lc = tabs.log[c as usize];
    for (i, tbl) in t.iter_mut().enumerate() {
        for (n, slot) in tbl.iter_mut().enumerate().skip(1) {
            *slot = tabs.exp[(lc + tabs.log[n << (4 * i)]) as usize] as u16;
        }
    }
    t
}

/// Split the four `u16` nibble tables into low/high byte planes — the form
/// the byte shuffles consume.
#[cfg_attr(not(any(target_arch = "x86_64", target_arch = "aarch64")), allow(dead_code))]
fn planes16(t: &[[u16; 16]; 4]) -> ([[u8; 16]; 4], [[u8; 16]; 4]) {
    let mut lo = [[0u8; 16]; 4];
    let mut hi = [[0u8; 16]; 4];
    for ((tw, tl), th) in t.iter().zip(lo.iter_mut()).zip(hi.iter_mut()) {
        for ((w, l), h) in tw.iter().zip(tl.iter_mut()).zip(th.iter_mut()) {
            *l = *w as u8;
            *h = (*w >> 8) as u8;
        }
    }
    (lo, hi)
}

/// Scalar nibble-table product for one GF(2^16) word (SIMD tail handling).
#[inline]
fn nib_mul16(t: &[[u16; 16]; 4], x: u16) -> u16 {
    t[0][(x & 0xF) as usize]
        ^ t[1][((x >> 4) & 0xF) as usize]
        ^ t[2][((x >> 8) & 0xF) as usize]
        ^ t[3][(x >> 12) as usize]
}

// ---------------------------------------------------------------------------
// GFNI affine-matrix encoding
// ---------------------------------------------------------------------------

/// Encode multiply-by-`c` over GF(2^8)/0x11D as the 8×8 GF(2) bit-matrix
/// `GF2P8AFFINEQB` consumes.
///
/// The instruction computes `dst.bit[i] = parity(matrix.byte[7-i] & src)`
/// per byte, i.e. qword byte `7-i` holds the row producing output bit `i`,
/// and bit `k` of that row multiplies source bit `k`. Multiplication by a
/// constant is GF(2)-linear, so row `i`, column `k` is bit `i` of
/// `c·x^k mod 0x11D`.
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
fn affine_matrix8(c: u8) -> u64 {
    let mut rows = [0u8; 8]; // rows[j] = matrix qword byte j
    for k in 0..8u32 {
        let prod = mul_bitwise(c as u32, 1 << k, 8);
        for i in 0..8usize {
            if prod >> i & 1 != 0 {
                rows[7 - i] |= 1 << k;
            }
        }
    }
    u64::from_le_bytes(rows)
}

/// The four 8×8 quadrants `[ll, lh, hl, hh]` of the 16×16 GF(2) matrix
/// for multiply-by-`c` over GF(2^16)/0x1100B, each in `GF2P8AFFINEQB`
/// layout: on the deinterleaved little-endian byte planes,
/// `lo' = ll·lo ⊕ lh·hi` and `hi' = hl·lo ⊕ hh·hi`.
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
fn affine_matrices16(c: u16) -> [u64; 4] {
    let mut rows = [[0u8; 8]; 4]; // ll, lh, hl, hh
    for k in 0..16u32 {
        let prod = mul_bitwise(c as u32, 1 << k, 16);
        for i in 0..16usize {
            if prod >> i & 1 != 0 {
                // output bits 0..7 are the lo plane (quadrants ll/lh),
                // 8..15 the hi plane (hl/hh); input bit k picks the column
                // plane the same way.
                let q = 2 * (i / 8) + (k as usize) / 8;
                rows[q][7 - (i % 8)] |= 1 << (k % 8);
            }
        }
    }
    rows.map(u64::from_le_bytes)
}

// ---------------------------------------------------------------------------
// Scalar kernels (the always-available fallback)
// ---------------------------------------------------------------------------

mod scalar {
    use crate::gf::tables::{product_table8, product_tables16};

    /// `dst ^= c·src` (XOR=true) / `dst = c·src` (XOR=false) over GF(2^8).
    pub fn mul8<const XOR: bool>(c: u8, src: &[u8], dst: &mut [u8]) {
        let t = product_table8(c);
        // 8-way unroll: keeps the table-lookup pipeline full on one core.
        let n = src.len();
        let chunks = n / 8 * 8;
        for i in (0..chunks).step_by(8) {
            if XOR {
                dst[i] ^= t[src[i] as usize];
                dst[i + 1] ^= t[src[i + 1] as usize];
                dst[i + 2] ^= t[src[i + 2] as usize];
                dst[i + 3] ^= t[src[i + 3] as usize];
                dst[i + 4] ^= t[src[i + 4] as usize];
                dst[i + 5] ^= t[src[i + 5] as usize];
                dst[i + 6] ^= t[src[i + 6] as usize];
                dst[i + 7] ^= t[src[i + 7] as usize];
            } else {
                dst[i] = t[src[i] as usize];
                dst[i + 1] = t[src[i + 1] as usize];
                dst[i + 2] = t[src[i + 2] as usize];
                dst[i + 3] = t[src[i + 3] as usize];
                dst[i + 4] = t[src[i + 4] as usize];
                dst[i + 5] = t[src[i + 5] as usize];
                dst[i + 6] = t[src[i + 6] as usize];
                dst[i + 7] = t[src[i + 7] as usize];
            }
        }
        for i in chunks..n {
            if XOR {
                dst[i] ^= t[src[i] as usize];
            } else {
                dst[i] = t[src[i] as usize];
            }
        }
    }

    /// `dst ^= c·src` / `dst = c·src` over GF(2^16) on little-endian byte
    /// pairs (length must be even; the dispatcher checks).
    pub fn mul16<const XOR: bool>(c: u16, src: &[u8], dst: &mut [u8]) {
        let (lo, hi) = product_tables16(c);
        for (d, s) in dst.chunks_exact_mut(2).zip(src.chunks_exact(2)) {
            let p = lo[s[0] as usize] ^ hi[s[1] as usize];
            let v = if XOR {
                u16::from_le_bytes([d[0], d[1]]) ^ p
            } else {
                p
            };
            d.copy_from_slice(&v.to_le_bytes());
        }
    }

    /// Fused dual-table GF(2^8) pass: `x ^= p·s, c ^= q·s` with one read
    /// of every source byte (the former `backend::native::fused_step8`).
    pub fn mul2_8(p: u8, q: u8, src: &[u8], x_dst: &mut [u8], c_dst: &mut [u8]) {
        let tp = product_table8(p);
        let tq = product_table8(q);
        for ((s, x), c) in src.iter().zip(x_dst.iter_mut()).zip(c_dst.iter_mut()) {
            let si = *s as usize;
            *x ^= tp[si];
            *c ^= tq[si];
        }
    }

    /// Fused dual split-table GF(2^16) pass: one read of each word feeds
    /// both products (the former `backend::native::fused_step16`).
    pub fn mul2_16(p: u16, q: u16, src: &[u8], x_dst: &mut [u8], c_dst: &mut [u8]) {
        let (plo, phi) = product_tables16(p);
        let (qlo, qhi) = product_tables16(q);
        for ((s, x), c) in src
            .chunks_exact(2)
            .zip(x_dst.chunks_exact_mut(2))
            .zip(c_dst.chunks_exact_mut(2))
        {
            let (b0, b1) = (s[0] as usize, s[1] as usize);
            let xv = u16::from_le_bytes([x[0], x[1]]) ^ plo[b0] ^ phi[b1];
            x.copy_from_slice(&xv.to_le_bytes());
            let cv = u16::from_le_bytes([c[0], c[1]]) ^ qlo[b0] ^ qhi[b1];
            c.copy_from_slice(&cv.to_le_bytes());
        }
    }

    /// `dst ^= src`, 8 bytes per step via `u64` words (any alignment —
    /// the words are assembled with `from_ne_bytes`).
    pub fn xor_wide(src: &[u8], dst: &mut [u8]) {
        for (d, s) in dst.chunks_exact_mut(8).zip(src.chunks_exact(8)) {
            let dv = u64::from_ne_bytes(<[u8; 8]>::try_from(&d[..]).unwrap());
            let sv = u64::from_ne_bytes(<[u8; 8]>::try_from(s).unwrap());
            d.copy_from_slice(&(dv ^ sv).to_ne_bytes());
        }
        let n = src.len();
        let done = n / 8 * 8;
        for i in done..n {
            dst[i] ^= src[i];
        }
    }
}

// ---------------------------------------------------------------------------
// x86-64 kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// GF(2^8) split-nibble pass, 16 bytes per step. Returns bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified SSSE3 support.
    #[target_feature(enable = "ssse3")]
    pub unsafe fn mul8_ssse3<const XOR: bool>(
        tlo: &[u8; 16],
        thi: &[u8; 16],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let lo = _mm_loadu_si128(tlo.as_ptr() as *const __m128i);
        let hi = _mm_loadu_si128(thi.as_ptr() as *const __m128i);
        let nib = _mm_set1_epi8(0x0F);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 16 <= n {
            let s = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let ln = _mm_and_si128(s, nib);
            let hn = _mm_and_si128(_mm_srli_epi64::<4>(s), nib);
            let mut p = _mm_xor_si128(_mm_shuffle_epi8(lo, ln), _mm_shuffle_epi8(hi, hn));
            if XOR {
                p = _mm_xor_si128(p, _mm_loadu_si128(dst.as_ptr().add(i) as *const __m128i));
            }
            _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, p);
            i += 16;
        }
        i
    }

    /// GF(2^8) split-nibble pass, 32 bytes per step. Returns bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul8_avx2<const XOR: bool>(
        tlo: &[u8; 16],
        thi: &[u8; 16],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let lo = _mm256_broadcastsi128_si256(_mm_loadu_si128(tlo.as_ptr() as *const __m128i));
        let hi = _mm256_broadcastsi128_si256(_mm_loadu_si128(thi.as_ptr() as *const __m128i));
        let nib = _mm256_set1_epi8(0x0F);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let ln = _mm256_and_si256(s, nib);
            let hn = _mm256_and_si256(_mm256_srli_epi64::<4>(s), nib);
            let mut p =
                _mm256_xor_si256(_mm256_shuffle_epi8(lo, ln), _mm256_shuffle_epi8(hi, hn));
            if XOR {
                p = _mm256_xor_si256(
                    p,
                    _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i),
                );
            }
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, p);
            i += 32;
        }
        i
    }

    /// GF(2^16) four-nibble pass over little-endian byte pairs, 16 words
    /// (32 bytes) per step: deinterleave the lo/hi source bytes with
    /// pack/shift, shuffle the four byte-plane tables, reinterleave with
    /// unpack. Returns bytes done (a multiple of 32).
    ///
    /// # Safety
    /// Caller must have runtime-verified SSSE3 support.
    #[target_feature(enable = "ssse3")]
    pub unsafe fn mul16_ssse3<const XOR: bool>(
        plo: &[[u8; 16]; 4],
        phi: &[[u8; 16]; 4],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let t: [__m128i; 4] = [
            _mm_loadu_si128(plo[0].as_ptr() as *const __m128i),
            _mm_loadu_si128(plo[1].as_ptr() as *const __m128i),
            _mm_loadu_si128(plo[2].as_ptr() as *const __m128i),
            _mm_loadu_si128(plo[3].as_ptr() as *const __m128i),
        ];
        let u: [__m128i; 4] = [
            _mm_loadu_si128(phi[0].as_ptr() as *const __m128i),
            _mm_loadu_si128(phi[1].as_ptr() as *const __m128i),
            _mm_loadu_si128(phi[2].as_ptr() as *const __m128i),
            _mm_loadu_si128(phi[3].as_ptr() as *const __m128i),
        ];
        let nib = _mm_set1_epi8(0x0F);
        let bytemask = _mm_set1_epi16(0x00FF);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let v0 = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let v1 = _mm_loadu_si128(src.as_ptr().add(i + 16) as *const __m128i);
            // deinterleave: lo = low bytes of the 16 words, hi = high bytes
            let lo = _mm_packus_epi16(_mm_and_si128(v0, bytemask), _mm_and_si128(v1, bytemask));
            let hi = _mm_packus_epi16(_mm_srli_epi16::<8>(v0), _mm_srli_epi16::<8>(v1));
            let n0 = _mm_and_si128(lo, nib);
            let n1 = _mm_and_si128(_mm_srli_epi64::<4>(lo), nib);
            let n2 = _mm_and_si128(hi, nib);
            let n3 = _mm_and_si128(_mm_srli_epi64::<4>(hi), nib);
            let rlo = _mm_xor_si128(
                _mm_xor_si128(_mm_shuffle_epi8(t[0], n0), _mm_shuffle_epi8(t[1], n1)),
                _mm_xor_si128(_mm_shuffle_epi8(t[2], n2), _mm_shuffle_epi8(t[3], n3)),
            );
            let rhi = _mm_xor_si128(
                _mm_xor_si128(_mm_shuffle_epi8(u[0], n0), _mm_shuffle_epi8(u[1], n1)),
                _mm_xor_si128(_mm_shuffle_epi8(u[2], n2), _mm_shuffle_epi8(u[3], n3)),
            );
            // reinterleave the product byte planes back into words
            let mut p0 = _mm_unpacklo_epi8(rlo, rhi);
            let mut p1 = _mm_unpackhi_epi8(rlo, rhi);
            if XOR {
                p0 = _mm_xor_si128(p0, _mm_loadu_si128(dst.as_ptr().add(i) as *const __m128i));
                p1 = _mm_xor_si128(
                    p1,
                    _mm_loadu_si128(dst.as_ptr().add(i + 16) as *const __m128i),
                );
            }
            _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, p0);
            _mm_storeu_si128(dst.as_mut_ptr().add(i + 16) as *mut __m128i, p1);
            i += 32;
        }
        i
    }

    /// GF(2^16) four-nibble pass, 32 words (64 bytes) per step. The
    /// pack/unpack pairs operate per 128-bit lane, and the composition
    /// pack → shuffle → unpack is lane-consistent, so the interleaved
    /// word layout round-trips exactly as in the SSE version. Returns
    /// bytes done (a multiple of 64).
    ///
    /// # Safety
    /// Caller must have runtime-verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul16_avx2<const XOR: bool>(
        plo: &[[u8; 16]; 4],
        phi: &[[u8; 16]; 4],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let t: [__m256i; 4] = [
            _mm256_broadcastsi128_si256(_mm_loadu_si128(plo[0].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(plo[1].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(plo[2].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(plo[3].as_ptr() as *const __m128i)),
        ];
        let u: [__m256i; 4] = [
            _mm256_broadcastsi128_si256(_mm_loadu_si128(phi[0].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(phi[1].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(phi[2].as_ptr() as *const __m128i)),
            _mm256_broadcastsi128_si256(_mm_loadu_si128(phi[3].as_ptr() as *const __m128i)),
        ];
        let nib = _mm256_set1_epi8(0x0F);
        let bytemask = _mm256_set1_epi16(0x00FF);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 64 <= n {
            let v0 = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let v1 = _mm256_loadu_si256(src.as_ptr().add(i + 32) as *const __m256i);
            let lo = _mm256_packus_epi16(
                _mm256_and_si256(v0, bytemask),
                _mm256_and_si256(v1, bytemask),
            );
            let hi = _mm256_packus_epi16(_mm256_srli_epi16::<8>(v0), _mm256_srli_epi16::<8>(v1));
            let n0 = _mm256_and_si256(lo, nib);
            let n1 = _mm256_and_si256(_mm256_srli_epi64::<4>(lo), nib);
            let n2 = _mm256_and_si256(hi, nib);
            let n3 = _mm256_and_si256(_mm256_srli_epi64::<4>(hi), nib);
            let rlo = _mm256_xor_si256(
                _mm256_xor_si256(_mm256_shuffle_epi8(t[0], n0), _mm256_shuffle_epi8(t[1], n1)),
                _mm256_xor_si256(_mm256_shuffle_epi8(t[2], n2), _mm256_shuffle_epi8(t[3], n3)),
            );
            let rhi = _mm256_xor_si256(
                _mm256_xor_si256(_mm256_shuffle_epi8(u[0], n0), _mm256_shuffle_epi8(u[1], n1)),
                _mm256_xor_si256(_mm256_shuffle_epi8(u[2], n2), _mm256_shuffle_epi8(u[3], n3)),
            );
            let mut p0 = _mm256_unpacklo_epi8(rlo, rhi);
            let mut p1 = _mm256_unpackhi_epi8(rlo, rhi);
            if XOR {
                p0 = _mm256_xor_si256(
                    p0,
                    _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i),
                );
                p1 = _mm256_xor_si256(
                    p1,
                    _mm256_loadu_si256(dst.as_ptr().add(i + 32) as *const __m256i),
                );
            }
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, p0);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i + 32) as *mut __m256i, p1);
            i += 64;
        }
        i
    }

    /// Fused GF(2^8) split-nibble pass: `x ^= p·s, c ^= q·s`, 16 bytes
    /// per step — one source load feeds both coefficients' shuffles.
    /// Returns bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified SSSE3 support.
    #[target_feature(enable = "ssse3")]
    pub unsafe fn mul2_8_ssse3(
        tp: (&[u8; 16], &[u8; 16]),
        tq: (&[u8; 16], &[u8; 16]),
        src: &[u8],
        x_dst: &mut [u8],
        c_dst: &mut [u8],
    ) -> usize {
        let plo = _mm_loadu_si128(tp.0.as_ptr() as *const __m128i);
        let phi = _mm_loadu_si128(tp.1.as_ptr() as *const __m128i);
        let qlo = _mm_loadu_si128(tq.0.as_ptr() as *const __m128i);
        let qhi = _mm_loadu_si128(tq.1.as_ptr() as *const __m128i);
        let nib = _mm_set1_epi8(0x0F);
        let n = src.len().min(x_dst.len()).min(c_dst.len());
        let mut i = 0usize;
        while i + 16 <= n {
            let s = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let ln = _mm_and_si128(s, nib);
            let hn = _mm_and_si128(_mm_srli_epi64::<4>(s), nib);
            let px = _mm_xor_si128(_mm_shuffle_epi8(plo, ln), _mm_shuffle_epi8(phi, hn));
            let qx = _mm_xor_si128(_mm_shuffle_epi8(qlo, ln), _mm_shuffle_epi8(qhi, hn));
            let x = _mm_xor_si128(px, _mm_loadu_si128(x_dst.as_ptr().add(i) as *const __m128i));
            let c = _mm_xor_si128(qx, _mm_loadu_si128(c_dst.as_ptr().add(i) as *const __m128i));
            _mm_storeu_si128(x_dst.as_mut_ptr().add(i) as *mut __m128i, x);
            _mm_storeu_si128(c_dst.as_mut_ptr().add(i) as *mut __m128i, c);
            i += 16;
        }
        i
    }

    /// Fused GF(2^8) split-nibble pass, 32 bytes per step. Returns bytes
    /// done.
    ///
    /// # Safety
    /// Caller must have runtime-verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul2_8_avx2(
        tp: (&[u8; 16], &[u8; 16]),
        tq: (&[u8; 16], &[u8; 16]),
        src: &[u8],
        x_dst: &mut [u8],
        c_dst: &mut [u8],
    ) -> usize {
        let plo = _mm256_broadcastsi128_si256(_mm_loadu_si128(tp.0.as_ptr() as *const __m128i));
        let phi = _mm256_broadcastsi128_si256(_mm_loadu_si128(tp.1.as_ptr() as *const __m128i));
        let qlo = _mm256_broadcastsi128_si256(_mm_loadu_si128(tq.0.as_ptr() as *const __m128i));
        let qhi = _mm256_broadcastsi128_si256(_mm_loadu_si128(tq.1.as_ptr() as *const __m128i));
        let nib = _mm256_set1_epi8(0x0F);
        let n = src.len().min(x_dst.len()).min(c_dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let ln = _mm256_and_si256(s, nib);
            let hn = _mm256_and_si256(_mm256_srli_epi64::<4>(s), nib);
            let px =
                _mm256_xor_si256(_mm256_shuffle_epi8(plo, ln), _mm256_shuffle_epi8(phi, hn));
            let qx =
                _mm256_xor_si256(_mm256_shuffle_epi8(qlo, ln), _mm256_shuffle_epi8(qhi, hn));
            let x = _mm256_xor_si256(
                px,
                _mm256_loadu_si256(x_dst.as_ptr().add(i) as *const __m256i),
            );
            let c = _mm256_xor_si256(
                qx,
                _mm256_loadu_si256(c_dst.as_ptr().add(i) as *const __m256i),
            );
            _mm256_storeu_si256(x_dst.as_mut_ptr().add(i) as *mut __m256i, x);
            _mm256_storeu_si256(c_dst.as_mut_ptr().add(i) as *mut __m256i, c);
            i += 32;
        }
        i
    }

    /// Fused GF(2^16) four-nibble pass: deinterleave each 32-byte group
    /// of source words ONCE, feed both coefficients' byte-plane shuffles,
    /// update both destination accumulators. Returns bytes done (a
    /// multiple of 32).
    ///
    /// # Safety
    /// Caller must have runtime-verified SSSE3 support.
    #[target_feature(enable = "ssse3")]
    pub unsafe fn mul2_16_ssse3(
        tp: (&[[u8; 16]; 4], &[[u8; 16]; 4]),
        tq: (&[[u8; 16]; 4], &[[u8; 16]; 4]),
        src: &[u8],
        x_dst: &mut [u8],
        c_dst: &mut [u8],
    ) -> usize {
        let load4 = |p: &[[u8; 16]; 4]| -> [__m128i; 4] {
            [
                _mm_loadu_si128(p[0].as_ptr() as *const __m128i),
                _mm_loadu_si128(p[1].as_ptr() as *const __m128i),
                _mm_loadu_si128(p[2].as_ptr() as *const __m128i),
                _mm_loadu_si128(p[3].as_ptr() as *const __m128i),
            ]
        };
        let (pt, pu) = (load4(tp.0), load4(tp.1));
        let (qt, qu) = (load4(tq.0), load4(tq.1));
        let nib = _mm_set1_epi8(0x0F);
        let bytemask = _mm_set1_epi16(0x00FF);
        let n = src.len().min(x_dst.len()).min(c_dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let v0 = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let v1 = _mm_loadu_si128(src.as_ptr().add(i + 16) as *const __m128i);
            let lo = _mm_packus_epi16(_mm_and_si128(v0, bytemask), _mm_and_si128(v1, bytemask));
            let hi = _mm_packus_epi16(_mm_srli_epi16::<8>(v0), _mm_srli_epi16::<8>(v1));
            let n0 = _mm_and_si128(lo, nib);
            let n1 = _mm_and_si128(_mm_srli_epi64::<4>(lo), nib);
            let n2 = _mm_and_si128(hi, nib);
            let n3 = _mm_and_si128(_mm_srli_epi64::<4>(hi), nib);
            let plane = |t: &[__m128i; 4]| {
                _mm_xor_si128(
                    _mm_xor_si128(_mm_shuffle_epi8(t[0], n0), _mm_shuffle_epi8(t[1], n1)),
                    _mm_xor_si128(_mm_shuffle_epi8(t[2], n2), _mm_shuffle_epi8(t[3], n3)),
                )
            };
            let (prlo, prhi) = (plane(&pt), plane(&pu));
            let (qrlo, qrhi) = (plane(&qt), plane(&qu));
            let px0 = _mm_unpacklo_epi8(prlo, prhi);
            let px1 = _mm_unpackhi_epi8(prlo, prhi);
            let qx0 = _mm_unpacklo_epi8(qrlo, qrhi);
            let qx1 = _mm_unpackhi_epi8(qrlo, qrhi);
            let x0 = _mm_xor_si128(px0, _mm_loadu_si128(x_dst.as_ptr().add(i) as *const __m128i));
            let x1 = _mm_xor_si128(
                px1,
                _mm_loadu_si128(x_dst.as_ptr().add(i + 16) as *const __m128i),
            );
            let c0 = _mm_xor_si128(qx0, _mm_loadu_si128(c_dst.as_ptr().add(i) as *const __m128i));
            let c1 = _mm_xor_si128(
                qx1,
                _mm_loadu_si128(c_dst.as_ptr().add(i + 16) as *const __m128i),
            );
            _mm_storeu_si128(x_dst.as_mut_ptr().add(i) as *mut __m128i, x0);
            _mm_storeu_si128(x_dst.as_mut_ptr().add(i + 16) as *mut __m128i, x1);
            _mm_storeu_si128(c_dst.as_mut_ptr().add(i) as *mut __m128i, c0);
            _mm_storeu_si128(c_dst.as_mut_ptr().add(i + 16) as *mut __m128i, c1);
            i += 32;
        }
        i
    }

    /// Fused GF(2^16) four-nibble pass, 64 bytes per step (lane-consistent
    /// pack → shuffle → unpack as in `mul16_avx2`). Returns bytes done (a
    /// multiple of 64).
    ///
    /// # Safety
    /// Caller must have runtime-verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul2_16_avx2(
        tp: (&[[u8; 16]; 4], &[[u8; 16]; 4]),
        tq: (&[[u8; 16]; 4], &[[u8; 16]; 4]),
        src: &[u8],
        x_dst: &mut [u8],
        c_dst: &mut [u8],
    ) -> usize {
        let load4 = |p: &[[u8; 16]; 4]| -> [__m256i; 4] {
            [
                _mm256_broadcastsi128_si256(_mm_loadu_si128(p[0].as_ptr() as *const __m128i)),
                _mm256_broadcastsi128_si256(_mm_loadu_si128(p[1].as_ptr() as *const __m128i)),
                _mm256_broadcastsi128_si256(_mm_loadu_si128(p[2].as_ptr() as *const __m128i)),
                _mm256_broadcastsi128_si256(_mm_loadu_si128(p[3].as_ptr() as *const __m128i)),
            ]
        };
        let (pt, pu) = (load4(tp.0), load4(tp.1));
        let (qt, qu) = (load4(tq.0), load4(tq.1));
        let nib = _mm256_set1_epi8(0x0F);
        let bytemask = _mm256_set1_epi16(0x00FF);
        let n = src.len().min(x_dst.len()).min(c_dst.len());
        let mut i = 0usize;
        while i + 64 <= n {
            let v0 = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let v1 = _mm256_loadu_si256(src.as_ptr().add(i + 32) as *const __m256i);
            let lo = _mm256_packus_epi16(
                _mm256_and_si256(v0, bytemask),
                _mm256_and_si256(v1, bytemask),
            );
            let hi = _mm256_packus_epi16(_mm256_srli_epi16::<8>(v0), _mm256_srli_epi16::<8>(v1));
            let n0 = _mm256_and_si256(lo, nib);
            let n1 = _mm256_and_si256(_mm256_srli_epi64::<4>(lo), nib);
            let n2 = _mm256_and_si256(hi, nib);
            let n3 = _mm256_and_si256(_mm256_srli_epi64::<4>(hi), nib);
            let plane = |t: &[__m256i; 4]| {
                _mm256_xor_si256(
                    _mm256_xor_si256(
                        _mm256_shuffle_epi8(t[0], n0),
                        _mm256_shuffle_epi8(t[1], n1),
                    ),
                    _mm256_xor_si256(
                        _mm256_shuffle_epi8(t[2], n2),
                        _mm256_shuffle_epi8(t[3], n3),
                    ),
                )
            };
            let (prlo, prhi) = (plane(&pt), plane(&pu));
            let (qrlo, qrhi) = (plane(&qt), plane(&qu));
            let px0 = _mm256_unpacklo_epi8(prlo, prhi);
            let px1 = _mm256_unpackhi_epi8(prlo, prhi);
            let qx0 = _mm256_unpacklo_epi8(qrlo, qrhi);
            let qx1 = _mm256_unpackhi_epi8(qrlo, qrhi);
            let x0 = _mm256_xor_si256(
                px0,
                _mm256_loadu_si256(x_dst.as_ptr().add(i) as *const __m256i),
            );
            let x1 = _mm256_xor_si256(
                px1,
                _mm256_loadu_si256(x_dst.as_ptr().add(i + 32) as *const __m256i),
            );
            let c0 = _mm256_xor_si256(
                qx0,
                _mm256_loadu_si256(c_dst.as_ptr().add(i) as *const __m256i),
            );
            let c1 = _mm256_xor_si256(
                qx1,
                _mm256_loadu_si256(c_dst.as_ptr().add(i + 32) as *const __m256i),
            );
            _mm256_storeu_si256(x_dst.as_mut_ptr().add(i) as *mut __m256i, x0);
            _mm256_storeu_si256(x_dst.as_mut_ptr().add(i + 32) as *mut __m256i, x1);
            _mm256_storeu_si256(c_dst.as_mut_ptr().add(i) as *mut __m256i, c0);
            _mm256_storeu_si256(c_dst.as_mut_ptr().add(i + 32) as *mut __m256i, c1);
            i += 64;
        }
        i
    }

    /// GF(2^8) product via `GF2P8AFFINEQB`, 32 bytes per step: one affine
    /// instruction applies the coefficient's 8×8 bit-matrix to every
    /// byte. Returns bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified GFNI + AVX2 support.
    #[target_feature(enable = "gfni,avx2")]
    pub unsafe fn mul8_gfni<const XOR: bool>(m: u64, src: &[u8], dst: &mut [u8]) -> usize {
        let a = _mm256_set1_epi64x(m as i64);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let mut p = _mm256_gf2p8affine_epi64_epi8::<0>(s, a);
            if XOR {
                p = _mm256_xor_si256(
                    p,
                    _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i),
                );
            }
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, p);
            i += 32;
        }
        i
    }

    /// GF(2^16) product via `GF2P8AFFINEQB`, 64 bytes per step: the
    /// 16×16 coefficient matrix `[ll, lh, hl, hh]` acts blockwise on the
    /// deinterleaved lo/hi byte planes (`lo' = ll·lo ⊕ lh·hi`,
    /// `hi' = hl·lo ⊕ hh·hi`), four affines per 32 words. Returns bytes
    /// done (a multiple of 64).
    ///
    /// # Safety
    /// Caller must have runtime-verified GFNI + AVX2 support.
    #[target_feature(enable = "gfni,avx2")]
    pub unsafe fn mul16_gfni<const XOR: bool>(m: &[u64; 4], src: &[u8], dst: &mut [u8]) -> usize {
        let all = _mm256_set1_epi64x(m[0] as i64);
        let alh = _mm256_set1_epi64x(m[1] as i64);
        let ahl = _mm256_set1_epi64x(m[2] as i64);
        let ahh = _mm256_set1_epi64x(m[3] as i64);
        let bytemask = _mm256_set1_epi16(0x00FF);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 64 <= n {
            let v0 = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let v1 = _mm256_loadu_si256(src.as_ptr().add(i + 32) as *const __m256i);
            let lo = _mm256_packus_epi16(
                _mm256_and_si256(v0, bytemask),
                _mm256_and_si256(v1, bytemask),
            );
            let hi = _mm256_packus_epi16(_mm256_srli_epi16::<8>(v0), _mm256_srli_epi16::<8>(v1));
            let rlo = _mm256_xor_si256(
                _mm256_gf2p8affine_epi64_epi8::<0>(lo, all),
                _mm256_gf2p8affine_epi64_epi8::<0>(hi, alh),
            );
            let rhi = _mm256_xor_si256(
                _mm256_gf2p8affine_epi64_epi8::<0>(lo, ahl),
                _mm256_gf2p8affine_epi64_epi8::<0>(hi, ahh),
            );
            let mut p0 = _mm256_unpacklo_epi8(rlo, rhi);
            let mut p1 = _mm256_unpackhi_epi8(rlo, rhi);
            if XOR {
                p0 = _mm256_xor_si256(
                    p0,
                    _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i),
                );
                p1 = _mm256_xor_si256(
                    p1,
                    _mm256_loadu_si256(dst.as_ptr().add(i + 32) as *const __m256i),
                );
            }
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, p0);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i + 32) as *mut __m256i, p1);
            i += 64;
        }
        i
    }

    /// Fused GF(2^8) `GF2P8AFFINEQB` pass: one source load, two affine
    /// products, both accumulators updated. Returns bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified GFNI + AVX2 support.
    #[target_feature(enable = "gfni,avx2")]
    pub unsafe fn mul2_8_gfni(
        mp: u64,
        mq: u64,
        src: &[u8],
        x_dst: &mut [u8],
        c_dst: &mut [u8],
    ) -> usize {
        let ap = _mm256_set1_epi64x(mp as i64);
        let aq = _mm256_set1_epi64x(mq as i64);
        let n = src.len().min(x_dst.len()).min(c_dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let x = _mm256_xor_si256(
                _mm256_gf2p8affine_epi64_epi8::<0>(s, ap),
                _mm256_loadu_si256(x_dst.as_ptr().add(i) as *const __m256i),
            );
            let c = _mm256_xor_si256(
                _mm256_gf2p8affine_epi64_epi8::<0>(s, aq),
                _mm256_loadu_si256(c_dst.as_ptr().add(i) as *const __m256i),
            );
            _mm256_storeu_si256(x_dst.as_mut_ptr().add(i) as *mut __m256i, x);
            _mm256_storeu_si256(c_dst.as_mut_ptr().add(i) as *mut __m256i, c);
            i += 32;
        }
        i
    }

    /// Fused GF(2^16) `GF2P8AFFINEQB` pass: deinterleave once, apply both
    /// coefficients' quadrant matrices, update both accumulators. Returns
    /// bytes done (a multiple of 64).
    ///
    /// # Safety
    /// Caller must have runtime-verified GFNI + AVX2 support.
    #[target_feature(enable = "gfni,avx2")]
    pub unsafe fn mul2_16_gfni(
        mp: &[u64; 4],
        mq: &[u64; 4],
        src: &[u8],
        x_dst: &mut [u8],
        c_dst: &mut [u8],
    ) -> usize {
        let load4 = |m: &[u64; 4]| -> [__m256i; 4] {
            [
                _mm256_set1_epi64x(m[0] as i64),
                _mm256_set1_epi64x(m[1] as i64),
                _mm256_set1_epi64x(m[2] as i64),
                _mm256_set1_epi64x(m[3] as i64),
            ]
        };
        let p = load4(mp);
        let q = load4(mq);
        let bytemask = _mm256_set1_epi16(0x00FF);
        let n = src.len().min(x_dst.len()).min(c_dst.len());
        let mut i = 0usize;
        while i + 64 <= n {
            let v0 = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let v1 = _mm256_loadu_si256(src.as_ptr().add(i + 32) as *const __m256i);
            let lo = _mm256_packus_epi16(
                _mm256_and_si256(v0, bytemask),
                _mm256_and_si256(v1, bytemask),
            );
            let hi = _mm256_packus_epi16(_mm256_srli_epi16::<8>(v0), _mm256_srli_epi16::<8>(v1));
            let planes = |a: &[__m256i; 4]| {
                (
                    _mm256_xor_si256(
                        _mm256_gf2p8affine_epi64_epi8::<0>(lo, a[0]),
                        _mm256_gf2p8affine_epi64_epi8::<0>(hi, a[1]),
                    ),
                    _mm256_xor_si256(
                        _mm256_gf2p8affine_epi64_epi8::<0>(lo, a[2]),
                        _mm256_gf2p8affine_epi64_epi8::<0>(hi, a[3]),
                    ),
                )
            };
            let (prlo, prhi) = planes(&p);
            let (qrlo, qrhi) = planes(&q);
            let x0 = _mm256_xor_si256(
                _mm256_unpacklo_epi8(prlo, prhi),
                _mm256_loadu_si256(x_dst.as_ptr().add(i) as *const __m256i),
            );
            let x1 = _mm256_xor_si256(
                _mm256_unpackhi_epi8(prlo, prhi),
                _mm256_loadu_si256(x_dst.as_ptr().add(i + 32) as *const __m256i),
            );
            let c0 = _mm256_xor_si256(
                _mm256_unpacklo_epi8(qrlo, qrhi),
                _mm256_loadu_si256(c_dst.as_ptr().add(i) as *const __m256i),
            );
            let c1 = _mm256_xor_si256(
                _mm256_unpackhi_epi8(qrlo, qrhi),
                _mm256_loadu_si256(c_dst.as_ptr().add(i + 32) as *const __m256i),
            );
            _mm256_storeu_si256(x_dst.as_mut_ptr().add(i) as *mut __m256i, x0);
            _mm256_storeu_si256(x_dst.as_mut_ptr().add(i + 32) as *mut __m256i, x1);
            _mm256_storeu_si256(c_dst.as_mut_ptr().add(i) as *mut __m256i, c0);
            _mm256_storeu_si256(c_dst.as_mut_ptr().add(i + 32) as *mut __m256i, c1);
            i += 64;
        }
        i
    }

    /// `dst ^= src`, 16 bytes per step (SSE2 is x86-64 baseline). Returns
    /// bytes done.
    ///
    /// # Safety
    /// `src`/`dst` must be valid for the lengths given (plain slices are).
    pub unsafe fn xor_sse2(src: &[u8], dst: &mut [u8]) -> usize {
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 16 <= n {
            let s = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let d = _mm_loadu_si128(dst.as_ptr().add(i) as *const __m128i);
            _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, _mm_xor_si128(d, s));
            i += 16;
        }
        i
    }

    /// `dst ^= src`, 32 bytes per step. Returns bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_avx2(src: &[u8], dst: &mut [u8]) -> usize {
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let d = _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(
                dst.as_mut_ptr().add(i) as *mut __m256i,
                _mm256_xor_si256(d, s),
            );
            i += 32;
        }
        i
    }
}

// ---------------------------------------------------------------------------
// aarch64 kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// GF(2^8) split-nibble pass (`TBL`), 16 bytes per step. Returns
    /// bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified NEON support.
    pub unsafe fn mul8_neon<const XOR: bool>(
        tlo: &[u8; 16],
        thi: &[u8; 16],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let lo = vld1q_u8(tlo.as_ptr());
        let hi = vld1q_u8(thi.as_ptr());
        let nib = vdupq_n_u8(0x0F);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 16 <= n {
            let s = vld1q_u8(src.as_ptr().add(i));
            let mut p = veorq_u8(
                vqtbl1q_u8(lo, vandq_u8(s, nib)),
                vqtbl1q_u8(hi, vshrq_n_u8::<4>(s)),
            );
            if XOR {
                p = veorq_u8(p, vld1q_u8(dst.as_ptr().add(i)));
            }
            vst1q_u8(dst.as_mut_ptr().add(i), p);
            i += 16;
        }
        i
    }

    /// GF(2^16) four-nibble pass over little-endian byte pairs, 16 words
    /// (32 bytes) per step: `UZP` deinterleaves the lo/hi source bytes,
    /// `TBL` looks up the four byte-plane tables, `ZIP` reinterleaves.
    /// Returns bytes done (a multiple of 32).
    ///
    /// # Safety
    /// Caller must have runtime-verified NEON support.
    pub unsafe fn mul16_neon<const XOR: bool>(
        plo: &[[u8; 16]; 4],
        phi: &[[u8; 16]; 4],
        src: &[u8],
        dst: &mut [u8],
    ) -> usize {
        let t = [
            vld1q_u8(plo[0].as_ptr()),
            vld1q_u8(plo[1].as_ptr()),
            vld1q_u8(plo[2].as_ptr()),
            vld1q_u8(plo[3].as_ptr()),
        ];
        let u = [
            vld1q_u8(phi[0].as_ptr()),
            vld1q_u8(phi[1].as_ptr()),
            vld1q_u8(phi[2].as_ptr()),
            vld1q_u8(phi[3].as_ptr()),
        ];
        let nib = vdupq_n_u8(0x0F);
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let v0 = vld1q_u8(src.as_ptr().add(i));
            let v1 = vld1q_u8(src.as_ptr().add(i + 16));
            let lo = vuzp1q_u8(v0, v1); // low bytes of the 16 words
            let hi = vuzp2q_u8(v0, v1); // high bytes
            let n0 = vandq_u8(lo, nib);
            let n1 = vshrq_n_u8::<4>(lo);
            let n2 = vandq_u8(hi, nib);
            let n3 = vshrq_n_u8::<4>(hi);
            let rlo = veorq_u8(
                veorq_u8(vqtbl1q_u8(t[0], n0), vqtbl1q_u8(t[1], n1)),
                veorq_u8(vqtbl1q_u8(t[2], n2), vqtbl1q_u8(t[3], n3)),
            );
            let rhi = veorq_u8(
                veorq_u8(vqtbl1q_u8(u[0], n0), vqtbl1q_u8(u[1], n1)),
                veorq_u8(vqtbl1q_u8(u[2], n2), vqtbl1q_u8(u[3], n3)),
            );
            let mut p0 = vzip1q_u8(rlo, rhi);
            let mut p1 = vzip2q_u8(rlo, rhi);
            if XOR {
                p0 = veorq_u8(p0, vld1q_u8(dst.as_ptr().add(i)));
                p1 = veorq_u8(p1, vld1q_u8(dst.as_ptr().add(i + 16)));
            }
            vst1q_u8(dst.as_mut_ptr().add(i), p0);
            vst1q_u8(dst.as_mut_ptr().add(i + 16), p1);
            i += 32;
        }
        i
    }

    /// Fused GF(2^8) split-nibble pass: `x ^= p·s, c ^= q·s`, 16 bytes
    /// per step — one `TBL` source load feeds both coefficients. Returns
    /// bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified NEON support.
    pub unsafe fn mul2_8_neon(
        tp: (&[u8; 16], &[u8; 16]),
        tq: (&[u8; 16], &[u8; 16]),
        src: &[u8],
        x_dst: &mut [u8],
        c_dst: &mut [u8],
    ) -> usize {
        let plo = vld1q_u8(tp.0.as_ptr());
        let phi = vld1q_u8(tp.1.as_ptr());
        let qlo = vld1q_u8(tq.0.as_ptr());
        let qhi = vld1q_u8(tq.1.as_ptr());
        let nib = vdupq_n_u8(0x0F);
        let n = src.len().min(x_dst.len()).min(c_dst.len());
        let mut i = 0usize;
        while i + 16 <= n {
            let s = vld1q_u8(src.as_ptr().add(i));
            let ln = vandq_u8(s, nib);
            let hn = vshrq_n_u8::<4>(s);
            let px = veorq_u8(vqtbl1q_u8(plo, ln), vqtbl1q_u8(phi, hn));
            let qx = veorq_u8(vqtbl1q_u8(qlo, ln), vqtbl1q_u8(qhi, hn));
            vst1q_u8(
                x_dst.as_mut_ptr().add(i),
                veorq_u8(px, vld1q_u8(x_dst.as_ptr().add(i))),
            );
            vst1q_u8(
                c_dst.as_mut_ptr().add(i),
                veorq_u8(qx, vld1q_u8(c_dst.as_ptr().add(i))),
            );
            i += 16;
        }
        i
    }

    /// Fused GF(2^16) four-nibble pass: `UZP`-deinterleave the 16 source
    /// words once, feed both coefficients' byte-plane `TBL`s, update both
    /// accumulators. Returns bytes done (a multiple of 32).
    ///
    /// # Safety
    /// Caller must have runtime-verified NEON support.
    pub unsafe fn mul2_16_neon(
        tp: (&[[u8; 16]; 4], &[[u8; 16]; 4]),
        tq: (&[[u8; 16]; 4], &[[u8; 16]; 4]),
        src: &[u8],
        x_dst: &mut [u8],
        c_dst: &mut [u8],
    ) -> usize {
        let load4 = |p: &[[u8; 16]; 4]| -> [uint8x16_t; 4] {
            [
                vld1q_u8(p[0].as_ptr()),
                vld1q_u8(p[1].as_ptr()),
                vld1q_u8(p[2].as_ptr()),
                vld1q_u8(p[3].as_ptr()),
            ]
        };
        let (pt, pu) = (load4(tp.0), load4(tp.1));
        let (qt, qu) = (load4(tq.0), load4(tq.1));
        let nib = vdupq_n_u8(0x0F);
        let n = src.len().min(x_dst.len()).min(c_dst.len());
        let mut i = 0usize;
        while i + 32 <= n {
            let v0 = vld1q_u8(src.as_ptr().add(i));
            let v1 = vld1q_u8(src.as_ptr().add(i + 16));
            let lo = vuzp1q_u8(v0, v1);
            let hi = vuzp2q_u8(v0, v1);
            let n0 = vandq_u8(lo, nib);
            let n1 = vshrq_n_u8::<4>(lo);
            let n2 = vandq_u8(hi, nib);
            let n3 = vshrq_n_u8::<4>(hi);
            let plane = |t: &[uint8x16_t; 4]| {
                veorq_u8(
                    veorq_u8(vqtbl1q_u8(t[0], n0), vqtbl1q_u8(t[1], n1)),
                    veorq_u8(vqtbl1q_u8(t[2], n2), vqtbl1q_u8(t[3], n3)),
                )
            };
            let (prlo, prhi) = (plane(&pt), plane(&pu));
            let (qrlo, qrhi) = (plane(&qt), plane(&qu));
            vst1q_u8(
                x_dst.as_mut_ptr().add(i),
                veorq_u8(vzip1q_u8(prlo, prhi), vld1q_u8(x_dst.as_ptr().add(i))),
            );
            vst1q_u8(
                x_dst.as_mut_ptr().add(i + 16),
                veorq_u8(vzip2q_u8(prlo, prhi), vld1q_u8(x_dst.as_ptr().add(i + 16))),
            );
            vst1q_u8(
                c_dst.as_mut_ptr().add(i),
                veorq_u8(vzip1q_u8(qrlo, qrhi), vld1q_u8(c_dst.as_ptr().add(i))),
            );
            vst1q_u8(
                c_dst.as_mut_ptr().add(i + 16),
                veorq_u8(vzip2q_u8(qrlo, qrhi), vld1q_u8(c_dst.as_ptr().add(i + 16))),
            );
            i += 32;
        }
        i
    }

    /// `dst ^= src`, 16 bytes per step. Returns bytes done.
    ///
    /// # Safety
    /// Caller must have runtime-verified NEON support.
    pub unsafe fn xor_neon(src: &[u8], dst: &mut [u8]) -> usize {
        let n = src.len().min(dst.len());
        let mut i = 0usize;
        while i + 16 <= n {
            let s = vld1q_u8(src.as_ptr().add(i));
            let d = vld1q_u8(dst.as_ptr().add(i));
            vst1q_u8(dst.as_mut_ptr().add(i), veorq_u8(d, s));
            i += 16;
        }
        i
    }
}

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

/// Downgrade to scalar when the requested kernel can't run here — the
/// safety gate in front of every `unsafe` feature block.
#[inline]
fn usable(k: Kernel) -> Kernel {
    if k.is_available() {
        k
    } else {
        Kernel::Scalar
    }
}

fn mul8_dispatch<const XOR: bool>(k: Kernel, c: u8, src: &[u8], dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    let k = usable(k);
    if k == Kernel::Scalar {
        scalar::mul8::<XOR>(c, src, dst);
        return;
    }
    let (tlo, thi) = nib_tables8(c);
    let done = match k {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `usable` verified the feature at runtime.
        Kernel::Ssse3 => unsafe { x86::mul8_ssse3::<XOR>(&tlo, &thi, src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Kernel::Avx2 => unsafe { x86::mul8_avx2::<XOR>(&tlo, &thi, src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above (GFNI + AVX2 both verified).
        Kernel::Gfni => unsafe { x86::mul8_gfni::<XOR>(affine_matrix8(c), src, dst) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Kernel::Neon => unsafe { neon::mul8_neon::<XOR>(&tlo, &thi, src, dst) },
        _ => 0,
    };
    for i in done..src.len() {
        let s = src[i];
        let p = tlo[(s & 0x0F) as usize] ^ thi[(s >> 4) as usize];
        if XOR {
            dst[i] ^= p;
        } else {
            dst[i] = p;
        }
    }
}

fn mul16_dispatch<const XOR: bool>(k: Kernel, c: u16, src: &[u8], dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    assert_eq!(src.len() % 2, 0, "GF(2^16) payload must have even length");
    let k = usable(k);
    if k == Kernel::Scalar {
        scalar::mul16::<XOR>(c, src, dst);
        return;
    }
    let t = nib_tables16(c);
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    let (plo, phi) = planes16(&t);
    let done = match k {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `usable` verified the feature at runtime.
        Kernel::Ssse3 => unsafe { x86::mul16_ssse3::<XOR>(&plo, &phi, src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Kernel::Avx2 => unsafe { x86::mul16_avx2::<XOR>(&plo, &phi, src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above (GFNI + AVX2 both verified).
        Kernel::Gfni => unsafe { x86::mul16_gfni::<XOR>(&affine_matrices16(c), src, dst) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Kernel::Neon => unsafe { neon::mul16_neon::<XOR>(&plo, &phi, src, dst) },
        _ => 0,
    };
    let n = src.len();
    let mut i = done;
    while i < n {
        let p = nib_mul16(&t, u16::from_le_bytes([src[i], src[i + 1]]));
        let v = if XOR {
            u16::from_le_bytes([dst[i], dst[i + 1]]) ^ p
        } else {
            p
        };
        dst[i..i + 2].copy_from_slice(&v.to_le_bytes());
        i += 2;
    }
}

/// `dst[i] ^= c·src[i]` over GF(2^8) byte slices on the given kernel.
/// Handles every coefficient (0 and 1 included) — the slice layer
/// shortcuts them earlier only for work accounting and speed.
pub fn mul_xor8(k: Kernel, c: u8, src: &[u8], dst: &mut [u8]) {
    mul8_dispatch::<true>(k, c, src, dst);
}

/// `dst[i] = c·src[i]` over GF(2^8) byte slices on the given kernel.
pub fn mul8(k: Kernel, c: u8, src: &[u8], dst: &mut [u8]) {
    mul8_dispatch::<false>(k, c, src, dst);
}

/// `dst[i] ^= c·src[i]` over GF(2^16) little-endian byte pairs (length
/// must be even) on the given kernel. Works on any byte alignment.
pub fn mul_xor16(k: Kernel, c: u16, src: &[u8], dst: &mut [u8]) {
    mul16_dispatch::<true>(k, c, src, dst);
}

/// `dst[i] = c·src[i]` over GF(2^16) little-endian byte pairs on the
/// given kernel.
pub fn mul16(k: Kernel, c: u16, src: &[u8], dst: &mut [u8]) {
    mul16_dispatch::<false>(k, c, src, dst);
}

/// `dst ^= src` on the given kernel (u64 words on scalar, vector XOR on
/// the SIMD kernels).
pub fn xor_bytes(k: Kernel, src: &[u8], dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    let k = usable(k);
    let done = match k {
        Kernel::Scalar => {
            scalar::xor_wide(src, dst);
            return;
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: plain slices; SSE2 is x86-64 baseline.
        Kernel::Ssse3 => unsafe { x86::xor_sse2(src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `usable` verified AVX2 at runtime (Gfni implies AVX2).
        Kernel::Avx2 | Kernel::Gfni => unsafe { x86::xor_avx2(src, dst) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `usable` verified NEON at runtime.
        Kernel::Neon => unsafe { neon::xor_neon(src, dst) },
        _ => 0,
    };
    for i in done..src.len() {
        dst[i] ^= src[i];
    }
}

/// Fused two-coefficient pass over GF(2^8):
/// `x_dst[i] ^= p·src[i], c_dst[i] ^= q·src[i]` in ONE read of each
/// source byte — the RapidRAID relay stage (`x_out = x_in ⊕ ψ·loc,
/// c ⊕= ξ·loc`) as a single kernel. Handles every coefficient (0 and 1
/// included — their product tables degenerate correctly); callers may
/// still decompose those classes earlier for work accounting.
pub fn mul2_xor8(k: Kernel, p: u8, q: u8, src: &[u8], x_dst: &mut [u8], c_dst: &mut [u8]) {
    assert_eq!(src.len(), x_dst.len());
    assert_eq!(src.len(), c_dst.len());
    let k = usable(k);
    if k == Kernel::Scalar {
        scalar::mul2_8(p, q, src, x_dst, c_dst);
        return;
    }
    let (plo, phi) = nib_tables8(p);
    let (qlo, qhi) = nib_tables8(q);
    let done = match k {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `usable` verified the feature at runtime.
        Kernel::Ssse3 => unsafe {
            x86::mul2_8_ssse3((&plo, &phi), (&qlo, &qhi), src, x_dst, c_dst)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Kernel::Avx2 => unsafe { x86::mul2_8_avx2((&plo, &phi), (&qlo, &qhi), src, x_dst, c_dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above (GFNI + AVX2 both verified).
        Kernel::Gfni => unsafe {
            x86::mul2_8_gfni(affine_matrix8(p), affine_matrix8(q), src, x_dst, c_dst)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Kernel::Neon => unsafe { neon::mul2_8_neon((&plo, &phi), (&qlo, &qhi), src, x_dst, c_dst) },
        _ => 0,
    };
    for i in done..src.len() {
        let s = src[i];
        x_dst[i] ^= plo[(s & 0x0F) as usize] ^ phi[(s >> 4) as usize];
        c_dst[i] ^= qlo[(s & 0x0F) as usize] ^ qhi[(s >> 4) as usize];
    }
}

/// Fused two-coefficient pass over GF(2^16) little-endian byte pairs
/// (length must be even): `x_dst ^= p·src, c_dst ^= q·src` in one source
/// read. Works on any byte alignment.
pub fn mul2_xor16(k: Kernel, p: u16, q: u16, src: &[u8], x_dst: &mut [u8], c_dst: &mut [u8]) {
    assert_eq!(src.len(), x_dst.len());
    assert_eq!(src.len(), c_dst.len());
    assert_eq!(src.len() % 2, 0, "GF(2^16) payload must have even length");
    let k = usable(k);
    if k == Kernel::Scalar {
        scalar::mul2_16(p, q, src, x_dst, c_dst);
        return;
    }
    let tp = nib_tables16(p);
    let tq = nib_tables16(q);
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    let (pp, ph) = planes16(&tp);
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    let (qp, qh) = planes16(&tq);
    let done = match k {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `usable` verified the feature at runtime.
        Kernel::Ssse3 => unsafe { x86::mul2_16_ssse3((&pp, &ph), (&qp, &qh), src, x_dst, c_dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        Kernel::Avx2 => unsafe { x86::mul2_16_avx2((&pp, &ph), (&qp, &qh), src, x_dst, c_dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above (GFNI + AVX2 both verified).
        Kernel::Gfni => unsafe {
            x86::mul2_16_gfni(&affine_matrices16(p), &affine_matrices16(q), src, x_dst, c_dst)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Kernel::Neon => unsafe { neon::mul2_16_neon((&pp, &ph), (&qp, &qh), src, x_dst, c_dst) },
        _ => 0,
    };
    let n = src.len();
    let mut i = done;
    while i < n {
        let s = u16::from_le_bytes([src[i], src[i + 1]]);
        let xv = u16::from_le_bytes([x_dst[i], x_dst[i + 1]]) ^ nib_mul16(&tp, s);
        x_dst[i..i + 2].copy_from_slice(&xv.to_le_bytes());
        let cv = u16::from_le_bytes([c_dst[i], c_dst[i + 1]]) ^ nib_mul16(&tq, s);
        c_dst[i..i + 2].copy_from_slice(&cv.to_le_bytes());
        i += 2;
    }
}

/// L1 block size for the row-batched GEMM: each chunk's accumulators stay
/// cache-hot across the k source passes.
const GEMM_CHUNK: usize = 4096;

/// One GF(2^8) matrix cell: `dst ^= c·src` with the coefficient-class
/// shortcuts (0 → skip, 1 → XOR) applied at the cell level.
fn gemm_cell8(k: Kernel, c: u8, src: &[u8], dst: &mut [u8]) {
    match c {
        0 => {}
        1 => xor_bytes(k, src, dst),
        _ => mul_xor8(k, c, src, dst),
    }
}

fn gemm_cell16(k: Kernel, c: u16, src: &[u8], dst: &mut [u8]) {
    match c {
        0 => {}
        1 => xor_bytes(k, src, dst),
        _ => mul_xor16(k, c, src, dst),
    }
}

/// Row-batched GF(2^8) GEMM: `out[r] ^= Σ_j mat[r][j]·data[j]`, walking
/// the sources in L1-sized chunks with output rows interleaved in PAIRS —
/// each chunk of each source is read once per row pair (via
/// [`mul2_xor8`]) instead of once per row, and the chunk accumulators
/// stay cache-resident across all k sources. Shapes must agree
/// (`mat[r].len() == data.len()`, all blocks the same length as every
/// `out[r]`); accumulates into `out` (callers zero-fill for a plain
/// product).
pub fn gemm_rows8(k: Kernel, mat: &[Vec<u32>], data: &[&[u8]], out: &mut [Vec<u8>]) {
    assert_eq!(mat.len(), out.len());
    let len = out.first().map_or(0, |o| o.len());
    let mut start = 0usize;
    while start < len {
        let end = (start + GEMM_CHUNK).min(len);
        for (rows, outs) in mat.chunks(2).zip(out.chunks_mut(2)) {
            match outs {
                [o0, o1] => {
                    for (j, d) in data.iter().enumerate() {
                        let (p, q) = (rows[0][j] as u8, rows[1][j] as u8);
                        let src = &d[start..end];
                        match (p, q) {
                            (0, 0) => {}
                            (_, 0) => gemm_cell8(k, p, src, &mut o0[start..end]),
                            (0, _) => gemm_cell8(k, q, src, &mut o1[start..end]),
                            _ => mul2_xor8(
                                k,
                                p,
                                q,
                                src,
                                &mut o0[start..end],
                                &mut o1[start..end],
                            ),
                        }
                    }
                }
                [o0] => {
                    for (j, d) in data.iter().enumerate() {
                        gemm_cell8(k, rows[0][j] as u8, &d[start..end], &mut o0[start..end]);
                    }
                }
                _ => unreachable!("chunks(2) yields 1- or 2-row groups"),
            }
        }
        start = end;
    }
}

/// Row-batched GF(2^16) GEMM over little-endian byte pairs — same
/// pair-of-rows, L1-chunked schedule as [`gemm_rows8`]. Block length must
/// be even.
pub fn gemm_rows16(k: Kernel, mat: &[Vec<u32>], data: &[&[u8]], out: &mut [Vec<u8>]) {
    assert_eq!(mat.len(), out.len());
    let len = out.first().map_or(0, |o| o.len());
    assert_eq!(len % 2, 0, "GF(2^16) payload must have even length");
    let mut start = 0usize;
    while start < len {
        let end = (start + GEMM_CHUNK).min(len);
        for (rows, outs) in mat.chunks(2).zip(out.chunks_mut(2)) {
            match outs {
                [o0, o1] => {
                    for (j, d) in data.iter().enumerate() {
                        let (p, q) = (rows[0][j] as u16, rows[1][j] as u16);
                        let src = &d[start..end];
                        match (p, q) {
                            (0, 0) => {}
                            (_, 0) => gemm_cell16(k, p, src, &mut o0[start..end]),
                            (0, _) => gemm_cell16(k, q, src, &mut o1[start..end]),
                            _ => mul2_xor16(
                                k,
                                p,
                                q,
                                src,
                                &mut o0[start..end],
                                &mut o1[start..end],
                            ),
                        }
                    }
                }
                [o0] => {
                    for (j, d) in data.iter().enumerate() {
                        gemm_cell16(k, rows[0][j] as u16, &d[start..end], &mut o0[start..end]);
                    }
                }
                _ => unreachable!("chunks(2) yields 1- or 2-row groups"),
            }
        }
        start = end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::tables::mul_bitwise;
    use crate::util::rng::SplitMix64;

    #[test]
    fn kernel_names_roundtrip() {
        for k in Kernel::ALL {
            assert_eq!(Kernel::from_name(k.name()), Some(k));
            assert_eq!(format!("{k}"), k.name());
        }
        assert_eq!(Kernel::from_name("sse9"), None);
    }

    #[test]
    fn resolve_priorities() {
        // forced scalar beats everything
        assert_eq!(resolve(true, Some("avx2")), Kernel::Scalar);
        // an explicit available kernel wins over detection
        assert_eq!(resolve(false, Some("scalar")), Kernel::Scalar);
        // unknown / unavailable requests fall back to detection
        assert_eq!(resolve(false, Some("nonsense")), Kernel::detect());
        assert_eq!(resolve(false, None), Kernel::detect());
        for k in Kernel::available_kernels() {
            assert_eq!(resolve(false, Some(k.name())), k);
        }
    }

    #[test]
    fn detected_kernels_are_available_and_include_scalar() {
        let ks = Kernel::available_kernels();
        assert!(ks.contains(&Kernel::Scalar));
        assert!(ks.iter().all(|k| k.is_available()));
        assert!(Kernel::detect().is_available());
        assert!(Kernel::active().is_available());
    }

    /// Lengths that cover empty, sub-vector, exact-vector and straddling
    /// tails for every vector width in play (16/32/64 bytes).
    const LENS: [usize; 14] = [0, 1, 2, 3, 8, 15, 16, 17, 31, 32, 33, 63, 64, 257];

    #[test]
    fn mul_xor8_matches_bitwise_on_every_kernel() {
        let mut rng = SplitMix64::new(11);
        let base_src: Vec<u8> = (0..600).map(|_| rng.next_u64() as u8).collect();
        let base_dst: Vec<u8> = (0..600).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for c in [0u8, 1, 2, 3, 0x53, 0x8E, 255] {
                for len in LENS {
                    for off in 0..3usize {
                        let src = &base_src[off..off + len];
                        let mut dst = base_dst[off..off + len].to_vec();
                        mul_xor8(k, c, src, &mut dst);
                        for i in 0..len {
                            let expect = base_dst[off + i]
                                ^ mul_bitwise(c as u32, src[i] as u32, 8) as u8;
                            assert_eq!(dst[i], expect, "k={k} c={c} len={len} off={off} i={i}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mul8_overwrite_matches_bitwise_on_every_kernel() {
        let mut rng = SplitMix64::new(12);
        let src: Vec<u8> = (0..300).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for c in [0u8, 1, 7, 200] {
                let mut dst = vec![0xAAu8; src.len()];
                mul8(k, c, &src, &mut dst);
                for i in 0..src.len() {
                    assert_eq!(
                        dst[i] as u32,
                        mul_bitwise(c as u32, src[i] as u32, 8),
                        "k={k} c={c} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn mul_xor16_matches_bitwise_on_every_kernel() {
        let mut rng = SplitMix64::new(13);
        let base_src: Vec<u8> = (0..800).map(|_| rng.next_u64() as u8).collect();
        let base_dst: Vec<u8> = (0..800).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for c in [0u16, 1, 2, 0x1234, 0x8001, 0xFFFF] {
                for len in LENS.map(|l| l / 2 * 2) {
                    // odd byte offsets exercise unaligned vector loads
                    for off in [0usize, 1, 2, 3] {
                        let src = &base_src[off..off + len];
                        let mut dst = base_dst[off..off + len].to_vec();
                        mul_xor16(k, c, src, &mut dst);
                        let mut i = 0;
                        while i < len {
                            let x = u16::from_le_bytes([src[i], src[i + 1]]);
                            let d0 = u16::from_le_bytes([base_dst[off + i], base_dst[off + i + 1]]);
                            let expect = d0 ^ mul_bitwise(c as u32, x as u32, 16) as u16;
                            let got = u16::from_le_bytes([dst[i], dst[i + 1]]);
                            assert_eq!(got, expect, "k={k} c={c:#x} len={len} off={off} i={i}");
                            i += 2;
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mul16_overwrite_matches_bitwise_on_every_kernel() {
        let mut rng = SplitMix64::new(14);
        let src: Vec<u8> = (0..400).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for c in [0u16, 1, 9, 0xBEEF] {
                let mut dst = vec![0x55u8; src.len()];
                mul16(k, c, &src, &mut dst);
                let mut i = 0;
                while i < src.len() {
                    let x = u16::from_le_bytes([src[i], src[i + 1]]);
                    let got = u16::from_le_bytes([dst[i], dst[i + 1]]);
                    assert_eq!(got as u32, mul_bitwise(c as u32, x as u32, 16), "k={k} c={c:#x} i={i}");
                    i += 2;
                }
            }
        }
    }

    #[test]
    fn xor_bytes_matches_on_every_kernel() {
        let mut rng = SplitMix64::new(15);
        let src: Vec<u8> = (0..500).map(|_| rng.next_u64() as u8).collect();
        let orig: Vec<u8> = (0..500).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for len in LENS {
                for off in 0..2usize {
                    let mut dst = orig[off..off + len].to_vec();
                    xor_bytes(k, &src[off..off + len], &mut dst);
                    for i in 0..len {
                        assert_eq!(dst[i], orig[off + i] ^ src[off + i], "k={k} len={len} i={i}");
                    }
                }
            }
        }
    }

    #[test]
    fn unavailable_kernel_degrades_to_scalar() {
        // A kernel foreign to this arch must still produce correct output
        // (the dispatcher downgrades instead of entering unsafe blocks).
        let foreign = if cfg!(target_arch = "x86_64") {
            Kernel::Neon
        } else {
            Kernel::Avx2
        };
        if foreign.is_available() {
            return; // nothing to test on this host
        }
        let src = vec![7u8; 100];
        let mut dst = vec![1u8; 100];
        mul_xor8(foreign, 5, &src, &mut dst);
        let expect = 1 ^ mul_bitwise(5, 7, 8) as u8;
        assert!(dst.iter().all(|&b| b == expect));
    }

    #[test]
    fn nibble_tables_compose_the_product() {
        let (lo, hi) = nib_tables8(0x53);
        for x in 0u32..256 {
            let got = lo[(x & 0xF) as usize] ^ hi[(x >> 4) as usize];
            assert_eq!(got as u32, mul_bitwise(0x53, x, 8), "x={x}");
        }
        let t = nib_tables16(0x1234);
        for x in [0u32, 1, 0xFF, 0x100, 0xABCD, 0xFFFF] {
            assert_eq!(nib_mul16(&t, x as u16) as u32, mul_bitwise(0x1234, x, 16), "x={x}");
        }
    }

    /// Scalar reference for the affine encoding: apply the 8×8 bit-matrix
    /// exactly as `GF2P8AFFINEQB` does (row i in qword byte 7-i,
    /// `dst.bit[i] = parity(row & src)`).
    fn affine_apply8(m: u64, x: u8) -> u8 {
        let rows = m.to_le_bytes();
        let mut out = 0u8;
        for (i, row) in rows.iter().enumerate() {
            if (row & x).count_ones() & 1 != 0 {
                out |= 1 << (7 - i);
            }
        }
        out
    }

    #[test]
    fn affine_matrix8_encodes_the_product() {
        for c in [0u8, 1, 2, 3, 0x53, 0x8E, 255] {
            let m = affine_matrix8(c);
            for x in 0u32..256 {
                assert_eq!(
                    affine_apply8(m, x as u8) as u32,
                    mul_bitwise(c as u32, x, 8),
                    "c={c} x={x}"
                );
            }
        }
    }

    #[test]
    fn affine_matrices16_encode_the_product_blockwise() {
        for c in [0u16, 1, 2, 0x1234, 0x8001, 0xFFFF] {
            let [ll, lh, hl, hh] = affine_matrices16(c);
            for x in [0u32, 1, 0xFF, 0x100, 0xABCD, 0x8000, 0xFFFF] {
                let (xlo, xhi) = (x as u8, (x >> 8) as u8);
                let rlo = affine_apply8(ll, xlo) ^ affine_apply8(lh, xhi);
                let rhi = affine_apply8(hl, xlo) ^ affine_apply8(hh, xhi);
                let got = u16::from_le_bytes([rlo, rhi]) as u32;
                assert_eq!(got, mul_bitwise(c as u32, x, 16), "c={c:#x} x={x:#x}");
            }
        }
    }

    #[test]
    fn mul2_xor8_matches_two_single_passes() {
        let mut rng = SplitMix64::new(21);
        let base_src: Vec<u8> = (0..600).map(|_| rng.next_u64() as u8).collect();
        let base_x: Vec<u8> = (0..600).map(|_| rng.next_u64() as u8).collect();
        let base_c: Vec<u8> = (0..600).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for (p, q) in [(0u8, 0u8), (1, 0x53), (0x53, 1), (0x8E, 0xF0), (255, 2)] {
                for len in LENS {
                    for off in 0..3usize {
                        let src = &base_src[off..off + len];
                        let mut x = base_x[off..off + len].to_vec();
                        let mut c = base_c[off..off + len].to_vec();
                        mul2_xor8(k, p, q, src, &mut x, &mut c);
                        let mut ex = base_x[off..off + len].to_vec();
                        let mut ec = base_c[off..off + len].to_vec();
                        mul_xor8(Kernel::Scalar, p, src, &mut ex);
                        mul_xor8(Kernel::Scalar, q, src, &mut ec);
                        assert_eq!(x, ex, "x: k={k} p={p} q={q} len={len} off={off}");
                        assert_eq!(c, ec, "c: k={k} p={p} q={q} len={len} off={off}");
                    }
                }
            }
        }
    }

    #[test]
    fn mul2_xor16_matches_two_single_passes() {
        let mut rng = SplitMix64::new(22);
        let base_src: Vec<u8> = (0..800).map(|_| rng.next_u64() as u8).collect();
        let base_x: Vec<u8> = (0..800).map(|_| rng.next_u64() as u8).collect();
        let base_c: Vec<u8> = (0..800).map(|_| rng.next_u64() as u8).collect();
        for k in Kernel::available_kernels() {
            for (p, q) in [(0u16, 0u16), (1, 0x1234), (0x1234, 1), (0x8001, 0xFFFF)] {
                for len in LENS.map(|l| l / 2 * 2) {
                    for off in [0usize, 1, 2, 3] {
                        let src = &base_src[off..off + len];
                        let mut x = base_x[off..off + len].to_vec();
                        let mut c = base_c[off..off + len].to_vec();
                        mul2_xor16(k, p, q, src, &mut x, &mut c);
                        let mut ex = base_x[off..off + len].to_vec();
                        let mut ec = base_c[off..off + len].to_vec();
                        mul_xor16(Kernel::Scalar, p, src, &mut ex);
                        mul_xor16(Kernel::Scalar, q, src, &mut ec);
                        assert_eq!(x, ex, "x: k={k} p={p:#x} q={q:#x} len={len} off={off}");
                        assert_eq!(c, ec, "c: k={k} p={p:#x} q={q:#x} len={len} off={off}");
                    }
                }
            }
        }
    }

    #[test]
    fn gemm_rows_match_per_cell_reference() {
        let mut rng = SplitMix64::new(23);
        // 5 output rows (odd → exercises the unpaired-row arm), 3 sources,
        // length straddling one GEMM_CHUNK boundary.
        let len = GEMM_CHUNK + 130;
        let data_own: Vec<Vec<u8>> =
            (0..3).map(|_| (0..len).map(|_| rng.next_u64() as u8).collect()).collect();
        let data: Vec<&[u8]> = data_own.iter().map(|d| d.as_slice()).collect();
        let mat: Vec<Vec<u32>> = vec![
            vec![0, 0, 0],
            vec![1, 0, 2],
            vec![0x53, 1, 0],
            vec![7, 0x8E, 255],
            vec![0, 1, 1],
        ];
        for k in Kernel::available_kernels() {
            for w in [8u32, 16] {
                let mut out = vec![vec![0u8; len]; mat.len()];
                if w == 8 {
                    gemm_rows8(k, &mat, &data, &mut out);
                } else {
                    gemm_rows16(k, &mat, &data, &mut out);
                }
                for (row, o) in mat.iter().zip(&out) {
                    let mut expect = vec![0u8; len];
                    for (&c, d) in row.iter().zip(&data) {
                        if c == 0 {
                            continue;
                        }
                        if w == 8 {
                            mul_xor8(Kernel::Scalar, c as u8, d, &mut expect);
                        } else {
                            mul_xor16(Kernel::Scalar, c as u16, d, &mut expect);
                        }
                    }
                    assert_eq!(o, &expect, "k={k} w={w} row={row:?}");
                }
            }
        }
    }
}
