//! Field element trait and the two concrete fields (GF(2^8), GF(2^16)).
//!
//! All coding code in the crate is generic over [`GfElem`] so every
//! algorithm (RapidRAID construction, Cauchy RS, Gauss, census…) works
//! identically for the paper's *RR8* and *RR16* builds.

use super::tables::{self, Tables};

/// An element of GF(2^w) stored in a primitive integer (u8 / u16).
///
/// Addition is XOR (characteristic 2); multiplication is table based.
pub trait GfElem:
    Copy + Clone + Eq + PartialEq + std::fmt::Debug + std::hash::Hash + Default + Send + Sync + 'static
{
    /// Field width in bits.
    const BITS: u32;
    /// Multiplicative group order: 2^w − 1.
    const ORDER: u32;
    /// The additive identity.
    const ZERO: Self;
    /// The multiplicative identity.
    const ONE: Self;

    /// Backing tables for this field.
    fn tables() -> &'static Tables;

    /// Lossless widening.
    fn to_u32(self) -> u32;
    /// Truncating narrowing (value must fit in w bits).
    fn from_u32(v: u32) -> Self;

    /// Field addition (== subtraction): XOR.
    #[inline]
    fn add(self, other: Self) -> Self {
        Self::from_u32(self.to_u32() ^ other.to_u32())
    }

    /// Field multiplication via log/antilog tables.
    #[inline]
    fn mul(self, other: Self) -> Self {
        let (a, b) = (self.to_u32(), other.to_u32());
        if a == 0 || b == 0 {
            return Self::ZERO;
        }
        let t = Self::tables();
        Self::from_u32(t.exp[(t.log[a as usize] + t.log[b as usize]) as usize])
    }

    /// Multiplicative inverse. Panics on zero.
    #[inline]
    fn inv(self) -> Self {
        let a = self.to_u32();
        assert!(a != 0, "inverse of 0 in GF(2^{})", Self::BITS);
        let t = Self::tables();
        Self::from_u32(t.exp[((Self::ORDER - t.log[a as usize]) % Self::ORDER) as usize])
    }

    /// Field division: `self * other.inv()`. Panics if `other` is zero.
    #[inline]
    fn div(self, other: Self) -> Self {
        self.mul(other.inv())
    }

    /// `alpha^e` where alpha is the fixed generator (2).
    #[inline]
    fn alpha_pow(e: u32) -> Self {
        Self::from_u32(Self::tables().exp[(e % Self::ORDER) as usize])
    }

    /// Discrete log base alpha. Panics on zero.
    #[inline]
    fn log(self) -> u32 {
        let a = self.to_u32();
        assert!(a != 0, "log of 0");
        Self::tables().log[a as usize]
    }
}

/// GF(2^8) element (the paper's *RR8*; one byte per symbol).
#[derive(Copy, Clone, Eq, PartialEq, Debug, Hash, Default, PartialOrd, Ord)]
pub struct Gf256(pub u8);

impl GfElem for Gf256 {
    const BITS: u32 = 8;
    const ORDER: u32 = 255;
    const ZERO: Self = Gf256(0);
    const ONE: Self = Gf256(1);

    #[inline]
    fn tables() -> &'static Tables {
        tables::tables8()
    }
    #[inline]
    fn to_u32(self) -> u32 {
        self.0 as u32
    }
    #[inline]
    fn from_u32(v: u32) -> Self {
        debug_assert!(v <= 0xFF);
        Gf256(v as u8)
    }
}

/// GF(2^16) element (the paper's *RR16*; one 16-bit word per symbol).
#[derive(Copy, Clone, Eq, PartialEq, Debug, Hash, Default, PartialOrd, Ord)]
pub struct Gf65536(pub u16);

impl GfElem for Gf65536 {
    const BITS: u32 = 16;
    const ORDER: u32 = 65535;
    const ZERO: Self = Gf65536(0);
    const ONE: Self = Gf65536(1);

    #[inline]
    fn tables() -> &'static Tables {
        tables::tables16()
    }
    #[inline]
    fn to_u32(self) -> u32 {
        self.0 as u32
    }
    #[inline]
    fn from_u32(v: u32) -> Self {
        debug_assert!(v <= 0xFFFF);
        Gf65536(v as u16)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn axioms<F: GfElem>(seed: u64) {
        let mut rng = SplitMix64::new(seed);
        let mask = (1u64 << F::BITS) - 1;
        for _ in 0..300 {
            let a = F::from_u32((rng.next_u64() & mask) as u32);
            let b = F::from_u32((rng.next_u64() & mask) as u32);
            let c = F::from_u32((rng.next_u64() & mask) as u32);
            // commutativity
            assert_eq!(a.mul(b), b.mul(a));
            assert_eq!(a.add(b), b.add(a));
            // associativity
            assert_eq!(a.mul(b).mul(c), a.mul(b.mul(c)));
            // distributivity
            assert_eq!(a.mul(b.add(c)), a.mul(b).add(a.mul(c)));
            // identities
            assert_eq!(a.mul(F::ONE), a);
            assert_eq!(a.mul(F::ZERO), F::ZERO);
            assert_eq!(a.add(F::ZERO), a);
            // additive self-inverse (characteristic 2)
            assert_eq!(a.add(a), F::ZERO);
            // multiplicative inverse
            if a != F::ZERO {
                assert_eq!(a.mul(a.inv()), F::ONE);
                assert_eq!(a.div(a), F::ONE);
            }
        }
    }

    #[test]
    fn gf256_axioms() {
        axioms::<Gf256>(1);
    }

    #[test]
    fn gf65536_axioms() {
        axioms::<Gf65536>(2);
    }

    #[test]
    fn gf256_mul_matches_bitwise_exhaustive() {
        for a in 0u32..256 {
            for b in 0u32..256 {
                let expect = tables::mul_bitwise(a, b, 8);
                let got = Gf256(a as u8).mul(Gf256(b as u8)).0 as u32;
                assert_eq!(got, expect, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn alpha_pow_and_log_roundtrip() {
        for e in [0u32, 1, 7, 200, 254, 255, 300] {
            let x = Gf256::alpha_pow(e);
            assert_eq!(x.log(), e % 255);
        }
        for e in [0u32, 1, 65534, 65535, 70000] {
            let x = Gf65536::alpha_pow(e);
            assert_eq!(x.log(), e % 65535);
        }
    }

    #[test]
    #[should_panic(expected = "inverse of 0")]
    fn inv_zero_panics() {
        Gf256::ZERO.inv();
    }
}
