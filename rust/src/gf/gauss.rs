//! Gaussian elimination over GF(2^w): rank, inversion, linear solve.
//!
//! Used for (a) the dependency census (rank of every k-subset of generator
//! rows), (b) decoding (invert the surviving k×k generator submatrix), and
//! (c) verifying coefficient draws during the search for accidental-
//! dependency-free RapidRAID codes.

use super::field::GfElem;
use super::matrix::Matrix;

/// Rank of `m` over the field (non-destructive).
pub fn rank<F: GfElem>(m: &Matrix<F>) -> usize {
    let mut a = m.clone();
    let (rows, cols) = (a.rows(), a.cols());
    let mut r = 0;
    for c in 0..cols {
        // find pivot
        let piv = (r..rows).find(|&i| a[(i, c)] != F::ZERO);
        let Some(piv) = piv else { continue };
        a.swap_rows(r, piv);
        let inv = a[(r, c)].inv();
        for j in c..cols {
            let v = a[(r, j)].mul(inv);
            a[(r, j)] = v;
        }
        for i in 0..rows {
            if i != r && a[(i, c)] != F::ZERO {
                let f = a[(i, c)];
                for j in c..cols {
                    let t = f.mul(a[(r, j)]);
                    a[(i, j)] = a[(i, j)].add(t);
                }
            }
        }
        r += 1;
        if r == rows {
            break;
        }
    }
    r
}

/// True if the square matrix has full rank.
pub fn is_invertible<F: GfElem>(m: &Matrix<F>) -> bool {
    m.rows() == m.cols() && rank(m) == m.rows()
}

/// Inverse of a square matrix, or `None` if singular (Gauss–Jordan).
pub fn invert<F: GfElem>(m: &Matrix<F>) -> Option<Matrix<F>> {
    assert_eq!(m.rows(), m.cols(), "inverse of non-square matrix");
    let n = m.rows();
    let mut a = m.clone();
    let mut inv = Matrix::<F>::identity(n);
    for c in 0..n {
        let piv = (c..n).find(|&i| a[(i, c)] != F::ZERO)?;
        a.swap_rows(c, piv);
        inv.swap_rows(c, piv);
        let s = a[(c, c)].inv();
        for j in 0..n {
            let v = a[(c, j)].mul(s);
            a[(c, j)] = v;
            let w = inv[(c, j)].mul(s);
            inv[(c, j)] = w;
        }
        for i in 0..n {
            if i != c && a[(i, c)] != F::ZERO {
                let f = a[(i, c)];
                for j in 0..n {
                    let t = f.mul(a[(c, j)]);
                    a[(i, j)] = a[(i, j)].add(t);
                    let t2 = f.mul(inv[(c, j)]);
                    inv[(i, j)] = inv[(i, j)].add(t2);
                }
            }
        }
    }
    Some(inv)
}

/// Solve `A x = b` for square invertible `A`; `None` if singular.
pub fn solve<F: GfElem>(a: &Matrix<F>, b: &[F]) -> Option<Vec<F>> {
    let inv = invert(a)?;
    Some(inv.mul_vec(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::field::{Gf256, Gf65536};
    use crate::util::prop::forall;

    fn random_matrix<F: GfElem>(rng: &mut crate::util::SplitMix64, n: usize) -> Matrix<F> {
        let mask = (1u64 << F::BITS) - 1;
        Matrix::from_fn(n, n, |_, _| F::from_u32((rng.next_u64() & mask) as u32))
    }

    #[test]
    fn rank_of_identity() {
        assert_eq!(rank(&Matrix::<Gf256>::identity(5)), 5);
        assert_eq!(rank(&Matrix::<Gf65536>::identity(7)), 7);
    }

    #[test]
    fn rank_of_zero() {
        assert_eq!(rank(&Matrix::<Gf256>::zero(4, 4)), 0);
    }

    #[test]
    fn rank_detects_duplicate_rows() {
        let mut m = Matrix::<Gf256>::identity(3);
        let r0: Vec<Gf256> = m.row(0).to_vec();
        m.row_mut(2).copy_from_slice(&r0);
        assert_eq!(rank(&m), 2);
    }

    #[test]
    fn rank_detects_scaled_rows() {
        // row2 = 5 * row0 is dependent over the field even though bytes differ
        let mut m = Matrix::<Gf256>::zero(2, 3);
        for j in 0..3 {
            m[(0, j)] = Gf256((j + 1) as u8);
            m[(1, j)] = Gf256(5).mul(Gf256((j + 1) as u8));
        }
        assert_eq!(rank(&m), 1);
    }

    #[test]
    fn invert_roundtrip_cauchy() {
        let c = Matrix::<Gf256>::cauchy(6, 6);
        let inv = invert(&c).expect("cauchy is invertible");
        assert_eq!(c.mul(&inv), Matrix::identity(6));
        assert_eq!(inv.mul(&c), Matrix::identity(6));
    }

    #[test]
    fn invert_singular_returns_none() {
        let m = Matrix::<Gf256>::zero(3, 3);
        assert!(invert(&m).is_none());
        let mut m2 = Matrix::<Gf256>::identity(3);
        let r0 = m2.row(0).to_vec();
        m2.row_mut(1).copy_from_slice(&r0);
        assert!(invert(&m2).is_none());
    }

    #[test]
    fn solve_recovers_known_vector() {
        let a = Matrix::<Gf256>::cauchy(5, 5);
        let x: Vec<Gf256> = (1..=5).map(|i| Gf256(i * 17)).collect();
        let b = a.mul_vec(&x);
        let got = solve(&a, &b).unwrap();
        assert_eq!(got, x);
    }

    #[test]
    fn prop_invert_roundtrip_random() {
        forall(40, 99, |rng| {
            let n = 1 + (rng.below(6) as usize);
            let m = random_matrix::<Gf256>(rng, n);
            if let Some(inv) = invert(&m) {
                assert_eq!(m.mul(&inv), Matrix::identity(n));
            } else {
                assert!(rank(&m) < n, "invert returned None on full-rank matrix");
            }
        });
    }

    #[test]
    fn prop_rank_bounded_gf65536() {
        forall(20, 100, |rng| {
            let n = 1 + (rng.below(5) as usize);
            let m = random_matrix::<Gf65536>(rng, n);
            let r = rank(&m);
            assert!(r <= n);
        });
    }
}
