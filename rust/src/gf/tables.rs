//! Log/antilog table construction for GF(2^8) and GF(2^16).
//!
//! Tables are built once at first use (`std::sync::OnceLock`) from the
//! bit-level carry-less multiply, exactly mirroring
//! `python/compile/gf.py::tables` — including the *doubled* antilog table so
//! `exp[log[a] + log[b]]` never needs a modular reduction.

use std::sync::OnceLock;

/// Primitive polynomial for GF(2^8): x^8 + x^4 + x^3 + x^2 + 1.
pub const POLY8: u32 = 0x11D;
/// Primitive polynomial for GF(2^16): x^16 + x^12 + x^3 + x + 1.
pub const POLY16: u32 = 0x1100B;

/// Carry-less "Russian peasant" multiply reduced mod the field polynomial.
/// Bit-level ground truth; used only to build tables and in tests.
pub fn mul_bitwise(mut a: u32, mut b: u32, w: u32) -> u32 {
    let (poly, top, mask) = match w {
        8 => (POLY8, 1u32 << 8, 0xFFu32),
        16 => (POLY16, 1u32 << 16, 0xFFFFu32),
        _ => panic!("unsupported field width {w}"),
    };
    debug_assert!(a <= mask && b <= mask);
    let mut r = 0u32;
    while b != 0 {
        if b & 1 != 0 {
            r ^= a;
        }
        b >>= 1;
        a <<= 1;
        if a & top != 0 {
            a ^= poly;
        }
    }
    r & mask
}

/// Log + doubled-antilog tables for one field.
pub struct Tables {
    /// `log[x]` for x in 1..=order; `log[0]` is 0 and must be guarded.
    pub log: Vec<u32>,
    /// `exp[i] = alpha^(i mod order)` for i in 0..2*order+2 (doubled).
    pub exp: Vec<u32>,
    /// Multiplicative group order: 2^w - 1.
    pub order: u32,
}

fn build(w: u32) -> Tables {
    let order: u32 = (1u32 << w) - 1;
    let mut log = vec![0u32; order as usize + 1];
    let mut exp = vec![0u32; 2 * order as usize + 2];
    let mut x = 1u32;
    for i in 0..order {
        exp[i as usize] = x;
        log[x as usize] = i;
        x = mul_bitwise(x, 2, w);
    }
    assert_eq!(x, 1, "polynomial is not primitive for w={w}");
    let (lo, hi) = exp.split_at_mut(order as usize);
    hi[..order as usize].copy_from_slice(lo);
    exp[2 * order as usize] = exp[0];
    exp[2 * order as usize + 1] = exp[1];
    Tables { log, exp, order }
}

/// 256-entry product table for one GF(2^8) coefficient: `t[x] = c·x`.
///
/// The single shared constructor behind every scalar bulk pass (the
/// scalar kernel, the fused two-output stage, the row-batched GEMM) —
/// built per call, cheap relative to the slice pass it feeds.
pub fn product_table8(c: u8) -> [u8; 256] {
    let mut t = [0u8; 256];
    if c == 0 {
        return t;
    }
    let tabs = tables8();
    let lc = tabs.log[c as usize];
    for (x, slot) in t.iter_mut().enumerate().skip(1) {
        *slot = tabs.exp[(lc + tabs.log[x]) as usize] as u8;
    }
    t
}

/// Two 256-entry split-byte product tables for one GF(2^16) coefficient:
/// `lo[b] = c·b`, `hi[b] = c·(b << 8)`, so
/// `c·x = lo[x & 0xFF] ⊕ hi[x >> 8]`.
pub fn product_tables16(c: u16) -> ([u16; 256], [u16; 256]) {
    let mut lo = [0u16; 256];
    let mut hi = [0u16; 256];
    if c == 0 {
        return (lo, hi);
    }
    let tabs = tables16();
    let lc = tabs.log[c as usize];
    for b in 1usize..256 {
        lo[b] = tabs.exp[(lc + tabs.log[b]) as usize] as u16;
        hi[b] = tabs.exp[(lc + tabs.log[b << 8]) as usize] as u16;
    }
    (lo, hi)
}

static TABLES8_CELL: OnceLock<Tables> = OnceLock::new();
static TABLES16_CELL: OnceLock<Tables> = OnceLock::new();

/// GF(2^8) tables (256-entry log, 512-entry exp).
pub fn tables8() -> &'static Tables {
    TABLES8_CELL.get_or_init(|| build(8))
}

/// GF(2^16) tables (65536-entry log, 131072-entry exp).
pub fn tables16() -> &'static Tables {
    TABLES16_CELL.get_or_init(|| build(16))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_gf256_products() {
        // Same pins as python/tests/test_gf_tables.py — both sides must agree.
        assert_eq!(mul_bitwise(0, 7, 8), 0);
        assert_eq!(mul_bitwise(1, 183, 8), 183);
        assert_eq!(mul_bitwise(2, 0x80, 8), 0x1D);
        assert_eq!(mul_bitwise(3, 7, 8), 9);
        assert_eq!(mul_bitwise(0xFF, 0xFF, 8), 226);
    }

    #[test]
    fn golden_gf65536_products() {
        assert_eq!(mul_bitwise(0, 1234, 16), 0);
        assert_eq!(mul_bitwise(1, 54321, 16), 54321);
        assert_eq!(mul_bitwise(2, 0x8000, 16), 0x100B);
        assert_eq!(mul_bitwise(0xFFFF, 0xFFFF, 16), 1843);
    }

    #[test]
    fn golden_table_rows() {
        let t = tables8();
        assert_eq!(&t.exp[..10], &[1, 2, 4, 8, 16, 32, 64, 128, 29, 58]);
        assert_eq!(&t.log[1..9], &[0, 1, 25, 2, 50, 26, 198, 3]);
        let t16 = tables16();
        assert_eq!(&t16.exp[14..18], &[16384, 32768, 4107, 8214]);
    }

    #[test]
    fn exp_table_is_doubled() {
        for t in [tables8(), tables16()] {
            let o = t.order as usize;
            assert_eq!(&t.exp[o..2 * o], &t.exp[..o]);
            // worst-case index log[a]+log[b] = 2*(order-1) must be in range
            assert!(t.exp.len() > 2 * (o - 1));
        }
    }

    #[test]
    fn every_nonzero_element_has_a_log() {
        let t = tables8();
        let mut seen = vec![false; 256];
        for i in 0..t.order as usize {
            seen[t.exp[i] as usize] = true;
        }
        assert!(seen[1..].iter().all(|&s| s));
        assert!(!seen[0]);
    }

    #[test]
    fn product_tables_match_bitwise() {
        for c in [0u8, 1, 2, 0x53, 0xFF] {
            let t = product_table8(c);
            for x in 0u32..256 {
                assert_eq!(t[x as usize] as u32, mul_bitwise(c as u32, x, 8), "c={c} x={x}");
            }
        }
        for c in [0u16, 1, 0x1234, 0xFFFF] {
            let (lo, hi) = product_tables16(c);
            for x in [0u32, 1, 0xFF, 0x100, 0xABCD, 0xFFFF] {
                let got = lo[(x & 0xFF) as usize] ^ hi[(x >> 8) as usize];
                assert_eq!(got as u32, mul_bitwise(c as u32, x, 16), "c={c} x={x}");
            }
        }
    }

    #[test]
    fn table_mul_matches_bitwise_gf256_exhaustive_diag() {
        let t = tables8();
        for a in 1u32..256 {
            for b in [1u32, 2, 3, 17, 91, 128, 255] {
                let expect = mul_bitwise(a, b, 8);
                let got = t.exp[(t.log[a as usize] + t.log[b as usize]) as usize];
                assert_eq!(got, expect, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn table_mul_matches_bitwise_gf65536_sampled() {
        let t = tables16();
        let mut s = 0x243F6A88u32; // deterministic LCG sample
        for _ in 0..2000 {
            s = s.wrapping_mul(1664525).wrapping_add(1013904223);
            let a = (s >> 8) & 0xFFFF;
            s = s.wrapping_mul(1664525).wrapping_add(1013904223);
            let b = (s >> 8) & 0xFFFF;
            if a == 0 || b == 0 {
                continue;
            }
            let expect = mul_bitwise(a, b, 16);
            let got = t.exp[(t.log[a as usize] + t.log[b as usize]) as usize];
            assert_eq!(got, expect, "a={a} b={b}");
        }
    }
}
