//! Parse a saved JSONL trace back into [`Event`]s ([`Event::to_json_line`]'s
//! inverse) — the input side of `rapidraid trace-report`.

use std::time::Duration;

use crate::clock::Tick;
use crate::metrics::json::{parse_json, JsonValue};
use crate::resources::GfWork;

use super::{Direction, Event, EventKind};

fn u64_field(obj: &JsonValue, key: &str) -> anyhow::Result<u64> {
    obj.get(key)
        .and_then(JsonValue::as_u64)
        .ok_or_else(|| anyhow::anyhow!("missing numeric field {key:?}"))
}

fn opt_u64_field(obj: &JsonValue, key: &str) -> Option<u64> {
    obj.get(key).and_then(JsonValue::as_u64)
}

fn tick_field(obj: &JsonValue, key: &str) -> anyhow::Result<Tick> {
    Ok(Duration::from_nanos(u64_field(obj, key)?))
}

/// Parse one canonical JSON trace line.
pub fn parse_event(line: &str) -> anyhow::Result<Event> {
    let obj = parse_json(line)?;
    let at = tick_field(&obj, "t")?;
    let node = opt_u64_field(&obj, "node").map(|n| n as usize);
    let name = obj
        .get("ev")
        .and_then(JsonValue::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing \"ev\" field"))?
        .to_string();
    let kind = match name.as_str() {
        "frame_sent" => EventKind::FrameSent {
            dst: u64_field(&obj, "dst")? as usize,
            bytes: u64_field(&obj, "bytes")? as usize,
            deliver_at: tick_field(&obj, "deliver")?,
        },
        "frame_recvd" => EventKind::FrameRecvd {
            src: u64_field(&obj, "src")? as usize,
            bytes: u64_field(&obj, "bytes")? as usize,
        },
        "nic_stall" => EventKind::NicStall {
            dir: match obj.get("dir").and_then(JsonValue::as_str) {
                Some("up") => Direction::Up,
                Some("down") => Direction::Down,
                other => anyhow::bail!("bad nic_stall dir {other:?}"),
            },
            stall: tick_field(&obj, "stall")?,
            busy: tick_field(&obj, "busy")?,
            bytes: u64_field(&obj, "bytes")? as usize,
        },
        "cpu_charge" => EventKind::CpuCharge {
            work: GfWork {
                mac_bytes: u64_field(&obj, "mac")?,
                xor_bytes: u64_field(&obj, "xor")?,
                store_bytes: u64_field(&obj, "store")?,
                invert_elems: u64_field(&obj, "inv")?,
            },
            cost: tick_field(&obj, "cost")?,
        },
        "fold_start" => EventKind::FoldStart {
            object: opt_u64_field(&obj, "object"),
            index: opt_u64_field(&obj, "index").map(|i| i as usize),
            frame: u64_field(&obj, "frame")? as usize,
        },
        "fold_end" => EventKind::FoldEnd {
            object: opt_u64_field(&obj, "object"),
            index: opt_u64_field(&obj, "index").map(|i| i as usize),
            frame: u64_field(&obj, "frame")? as usize,
        },
        "gemm_start" => EventKind::GemmStart {
            rows: u64_field(&obj, "rows")? as usize,
            frame: u64_field(&obj, "frame")? as usize,
        },
        "gemm_end" => EventKind::GemmEnd {
            rows: u64_field(&obj, "rows")? as usize,
            frame: u64_field(&obj, "frame")? as usize,
        },
        "store_done" => EventKind::StoreDone {
            object: u64_field(&obj, "object")?,
            index: u64_field(&obj, "index")? as usize,
            bytes: u64_field(&obj, "bytes")? as usize,
        },
        "queue_depth" => EventKind::QueueDepth {
            depth: u64_field(&obj, "depth")? as usize,
        },
        "node_failed" => EventKind::NodeFailed,
        "node_revived" => EventKind::NodeRevived,
        "repair_triggered" => EventKind::RepairTriggered {
            object: u64_field(&obj, "object")?,
            position: u64_field(&obj, "position")? as usize,
        },
        "repair_committed" => EventKind::RepairCommitted {
            object: u64_field(&obj, "object")?,
            position: u64_field(&obj, "position")? as usize,
            newcomer: u64_field(&obj, "newcomer")? as usize,
        },
        "plan_start" => EventKind::PlanStart {
            object: u64_field(&obj, "object")?,
            nodes: obj
                .get("nodes")
                .and_then(JsonValue::as_arr)
                .ok_or_else(|| anyhow::anyhow!("missing \"nodes\" array"))?
                .iter()
                .map(|v| {
                    v.as_u64()
                        .map(|n| n as usize)
                        .ok_or_else(|| anyhow::anyhow!("non-numeric node id"))
                })
                .collect::<anyhow::Result<Vec<usize>>>()?,
        },
        "plan_end" => EventKind::PlanEnd {
            object: u64_field(&obj, "object")?,
            makespan: tick_field(&obj, "makespan")?,
        },
        "epoch" => EventKind::Epoch {
            epoch: u64_field(&obj, "epoch")?,
            repaired: u64_field(&obj, "repaired")? as usize,
            missing: u64_field(&obj, "missing")? as usize,
        },
        other => anyhow::bail!("unknown event kind {other:?}"),
    };
    Ok(Event { at, node, kind })
}

/// Parse a whole JSONL document (blank lines skipped). Errors carry the
/// 1-based line number.
pub fn parse_jsonl(text: &str) -> anyhow::Result<Vec<Event>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let e =
            parse_event(line).map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 1))?;
        out.push(e);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_every_variant() {
        let samples = vec![
            Event {
                at: Duration::from_nanos(17),
                node: Some(2),
                kind: EventKind::FrameSent {
                    dst: 3,
                    bytes: 4096,
                    deliver_at: Duration::from_micros(9),
                },
            },
            Event {
                at: Duration::from_nanos(18),
                node: Some(3),
                kind: EventKind::FrameRecvd { src: 2, bytes: 4096 },
            },
            Event {
                at: Duration::from_nanos(19),
                node: Some(2),
                kind: EventKind::NicStall {
                    dir: Direction::Down,
                    stall: Duration::from_nanos(5),
                    busy: Duration::from_nanos(6),
                    bytes: 4096,
                },
            },
            Event {
                at: Duration::from_nanos(20),
                node: Some(1),
                kind: EventKind::CpuCharge {
                    work: GfWork {
                        mac_bytes: 1,
                        xor_bytes: 2,
                        store_bytes: 3,
                        invert_elems: 4,
                    },
                    cost: Duration::from_nanos(7),
                },
            },
            Event {
                at: Duration::from_nanos(21),
                node: Some(1),
                kind: EventKind::FoldStart {
                    object: Some(9),
                    index: Some(4),
                    frame: 0,
                },
            },
            Event {
                at: Duration::from_nanos(22),
                node: Some(1),
                kind: EventKind::FoldEnd {
                    object: None,
                    index: None,
                    frame: 0,
                },
            },
            Event {
                at: Duration::from_nanos(23),
                node: Some(5),
                kind: EventKind::GemmStart { rows: 3, frame: 1 },
            },
            Event {
                at: Duration::from_nanos(24),
                node: Some(5),
                kind: EventKind::GemmEnd { rows: 3, frame: 1 },
            },
            Event {
                at: Duration::from_nanos(25),
                node: Some(5),
                kind: EventKind::StoreDone {
                    object: 9,
                    index: 2,
                    bytes: 65536,
                },
            },
            Event {
                at: Duration::from_nanos(26),
                node: Some(0),
                kind: EventKind::QueueDepth { depth: 4 },
            },
            Event {
                at: Duration::from_nanos(27),
                node: Some(6),
                kind: EventKind::NodeFailed,
            },
            Event {
                at: Duration::from_nanos(28),
                node: Some(6),
                kind: EventKind::NodeRevived,
            },
            Event {
                at: Duration::from_nanos(29),
                node: Some(7),
                kind: EventKind::RepairTriggered {
                    object: 9,
                    position: 1,
                },
            },
            Event {
                at: Duration::from_nanos(30),
                node: Some(7),
                kind: EventKind::RepairCommitted {
                    object: 9,
                    position: 1,
                    newcomer: 7,
                },
            },
            Event {
                at: Duration::from_nanos(31),
                node: Some(0),
                kind: EventKind::PlanStart {
                    object: 9,
                    nodes: vec![0, 1, 2],
                },
            },
            Event {
                at: Duration::from_nanos(32),
                node: Some(0),
                kind: EventKind::PlanEnd {
                    object: 9,
                    makespan: Duration::from_nanos(1),
                },
            },
            Event {
                at: Duration::from_nanos(33),
                node: None,
                kind: EventKind::Epoch {
                    epoch: 2,
                    repaired: 1,
                    missing: 0,
                },
            },
        ];
        for e in &samples {
            let back = parse_event(&e.to_json_line()).unwrap();
            assert_eq!(&back, e, "round trip of {}", e.to_json_line());
        }
        let doc: String = samples
            .iter()
            .map(|e| e.to_json_line() + "\n")
            .collect();
        assert_eq!(parse_jsonl(&doc).unwrap(), samples);
    }

    #[test]
    fn bad_lines_name_their_line_number() {
        let err = parse_jsonl("{\"t\":1,\"ev\":\"frame_sent\"}\n").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
        assert!(parse_event("not json").is_err());
        assert!(parse_event("{\"t\":1,\"ev\":\"martian\"}").is_err());
    }
}
