//! Derived per-node / per-link counters over a raw event stream: bytes in
//! flight, NIC and CPU utilization, queue-depth gauges — the aggregate
//! load signals folded into `BenchJson` reports and printed by
//! `rapidraid trace-report`.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::clock::Tick;
use crate::cluster::NodeId;
use crate::metrics::BenchJson;

use super::{Event, EventKind};

/// Aggregates for one node.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NodeCounters {
    /// Node id.
    pub node: NodeId,
    /// Frames sent / received.
    pub frames_sent: u64,
    /// Frames received.
    pub frames_recvd: u64,
    /// Wire bytes sent.
    pub bytes_sent: u64,
    /// Wire bytes received.
    pub bytes_recvd: u64,
    /// Total virtual CPU time charged on the node's meter.
    pub cpu_busy: Tick,
    /// Total NIC wire-occupancy time (up + down reservations).
    pub nic_busy: Tick,
    /// Total time spent queued behind earlier NIC reservations.
    pub nic_stall: Tick,
    /// Highest observed command-queue depth.
    pub max_queue: usize,
    /// Blocks landed in the store.
    pub stores: u64,
    /// Bytes landed in the store.
    pub store_bytes: u64,
}

/// Aggregates for one directed link (src → dst).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LinkCounters {
    /// Sending node.
    pub src: NodeId,
    /// Receiving node.
    pub dst: NodeId,
    /// Frames carried.
    pub frames: u64,
    /// Wire bytes carried.
    pub bytes: u64,
    /// Peak bytes in flight (sent, not yet received).
    pub max_in_flight: u64,
}

/// Everything [`derive_counters`] computes over one trace.
#[derive(Clone, Debug, Default)]
pub struct TraceCounters {
    /// Events observed.
    pub events: usize,
    /// Time covered: first event tick → last event tick.
    pub span: Tick,
    /// Per-node aggregates, ordered by node id.
    pub nodes: Vec<NodeCounters>,
    /// Per-link aggregates, ordered by (src, dst).
    pub links: Vec<LinkCounters>,
}

impl TraceCounters {
    /// CPU utilization of `c` over the trace span, in percent (0 when the
    /// span is empty).
    pub fn cpu_util_pct(&self, c: &NodeCounters) -> f64 {
        pct(c.cpu_busy, self.span)
    }

    /// NIC wire-occupancy of `c` over the trace span, in percent.
    pub fn nic_util_pct(&self, c: &NodeCounters) -> f64 {
        pct(c.nic_busy, self.span)
    }

    /// Fold the headline gauges into a bench report as params
    /// (`trace_events`, `trace_span_ns`, byte totals, peak queue depth and
    /// the max per-node CPU/NIC utilization) so every traced `BENCH_*.json`
    /// is self-describing about the load it measured.
    pub fn fold_into(&self, report: &mut BenchJson) {
        let bytes_sent: u64 = self.nodes.iter().map(|n| n.bytes_sent).sum();
        let max_queue = self.nodes.iter().map(|n| n.max_queue).max().unwrap_or(0);
        let cpu_max = self
            .nodes
            .iter()
            .map(|n| self.cpu_util_pct(n))
            .fold(0.0f64, f64::max);
        let nic_max = self
            .nodes
            .iter()
            .map(|n| self.nic_util_pct(n))
            .fold(0.0f64, f64::max);
        report.set_param("trace_events", self.events);
        report.set_param("trace_span_ns", self.span.as_nanos());
        report.set_param("trace_bytes_sent", bytes_sent);
        report.set_param("trace_max_queue_depth", max_queue);
        report.set_param("trace_cpu_util_max_pct", format!("{cpu_max:.1}"));
        report.set_param("trace_nic_util_max_pct", format!("{nic_max:.1}"));
    }

    /// Human-readable per-node and per-link summary lines.
    pub fn summary_lines(&self) -> Vec<String> {
        let mut out = Vec::with_capacity(self.nodes.len() + self.links.len() + 1);
        out.push(format!(
            "{} events over {:?} on {} nodes / {} links",
            self.events,
            self.span,
            self.nodes.len(),
            self.links.len()
        ));
        for n in &self.nodes {
            out.push(format!(
                "node {:>3}: cpu {:>5.1}% nic {:>5.1}% (stall {:?}) sent {} B recvd {} B stores {} queue≤{}",
                n.node,
                self.cpu_util_pct(n),
                self.nic_util_pct(n),
                n.nic_stall,
                n.bytes_sent,
                n.bytes_recvd,
                n.stores,
                n.max_queue,
            ));
        }
        for l in &self.links {
            out.push(format!(
                "link {:>3} -> {:>3}: {} frames, {} B, peak {} B in flight",
                l.src, l.dst, l.frames, l.bytes, l.max_in_flight
            ));
        }
        out
    }
}

fn pct(busy: Tick, span: Tick) -> f64 {
    if span.is_zero() {
        return 0.0;
    }
    100.0 * busy.as_secs_f64() / span.as_secs_f64()
}

/// Scan a trace into per-node / per-link aggregates.
pub fn derive_counters(events: &[Event]) -> TraceCounters {
    let mut nodes: BTreeMap<NodeId, NodeCounters> = BTreeMap::new();
    let mut links: BTreeMap<(NodeId, NodeId), (LinkCounters, u64)> = BTreeMap::new();
    let mut first: Option<Tick> = None;
    let mut last = Duration::ZERO;

    for e in events {
        first = Some(first.map_or(e.at, |f| f.min(e.at)));
        last = last.max(e.at);
        let touch = |nodes: &mut BTreeMap<NodeId, NodeCounters>, id: NodeId| {
            nodes.entry(id).or_insert_with(|| NodeCounters {
                node: id,
                ..NodeCounters::default()
            });
        };
        match (&e.kind, e.node) {
            (
                EventKind::FrameSent {
                    dst,
                    bytes,
                    deliver_at,
                },
                Some(src),
            ) => {
                last = last.max(*deliver_at);
                touch(&mut nodes, src);
                let n = nodes.get_mut(&src).unwrap();
                n.frames_sent += 1;
                n.bytes_sent += *bytes as u64;
                let (link, in_flight) =
                    links
                        .entry((src, *dst))
                        .or_insert_with(|| {
                            (
                                LinkCounters {
                                    src,
                                    dst: *dst,
                                    ..LinkCounters::default()
                                },
                                0,
                            )
                        });
                link.frames += 1;
                link.bytes += *bytes as u64;
                *in_flight += *bytes as u64;
                link.max_in_flight = link.max_in_flight.max(*in_flight);
            }
            (EventKind::FrameRecvd { src, bytes }, Some(dst)) => {
                touch(&mut nodes, dst);
                let n = nodes.get_mut(&dst).unwrap();
                n.frames_recvd += 1;
                n.bytes_recvd += *bytes as u64;
                if let Some((_, in_flight)) = links.get_mut(&(*src, dst)) {
                    *in_flight = in_flight.saturating_sub(*bytes as u64);
                }
            }
            (EventKind::NicStall { stall, busy, .. }, Some(id)) => {
                touch(&mut nodes, id);
                let n = nodes.get_mut(&id).unwrap();
                n.nic_stall += *stall;
                n.nic_busy += *busy;
            }
            (EventKind::CpuCharge { cost, .. }, Some(id)) => {
                touch(&mut nodes, id);
                nodes.get_mut(&id).unwrap().cpu_busy += *cost;
            }
            (EventKind::QueueDepth { depth }, Some(id)) => {
                touch(&mut nodes, id);
                let n = nodes.get_mut(&id).unwrap();
                n.max_queue = n.max_queue.max(*depth);
            }
            (EventKind::StoreDone { bytes, .. }, Some(id)) => {
                touch(&mut nodes, id);
                let n = nodes.get_mut(&id).unwrap();
                n.stores += 1;
                n.store_bytes += *bytes as u64;
            }
            _ => {}
        }
    }
    TraceCounters {
        events: events.len(),
        span: last.saturating_sub(first.unwrap_or(Duration::ZERO)),
        nodes: nodes.into_values().collect(),
        links: links.into_values().map(|(l, _)| l).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::GfWork;
    use crate::trace::Direction;

    fn at(ns: u64) -> Tick {
        Duration::from_nanos(ns)
    }

    #[test]
    fn counters_aggregate_per_node_and_link() {
        let events = vec![
            Event {
                at: at(0),
                node: Some(0),
                kind: EventKind::FrameSent {
                    dst: 1,
                    bytes: 100,
                    deliver_at: at(50),
                },
            },
            Event {
                at: at(10),
                node: Some(0),
                kind: EventKind::FrameSent {
                    dst: 1,
                    bytes: 100,
                    deliver_at: at(60),
                },
            },
            Event {
                at: at(50),
                node: Some(1),
                kind: EventKind::FrameRecvd { src: 0, bytes: 100 },
            },
            Event {
                at: at(55),
                node: Some(1),
                kind: EventKind::CpuCharge {
                    work: GfWork::mac(64),
                    cost: at(500),
                },
            },
            Event {
                at: at(56),
                node: Some(1),
                kind: EventKind::NicStall {
                    dir: Direction::Up,
                    stall: at(5),
                    busy: at(250),
                    bytes: 100,
                },
            },
            Event {
                at: at(57),
                node: Some(1),
                kind: EventKind::QueueDepth { depth: 3 },
            },
            Event {
                at: at(1000),
                node: Some(1),
                kind: EventKind::StoreDone {
                    object: 1,
                    index: 0,
                    bytes: 4096,
                },
            },
        ];
        let c = derive_counters(&events);
        assert_eq!(c.events, 7);
        assert_eq!(c.span, at(1000));
        assert_eq!(c.nodes.len(), 2);
        let n0 = &c.nodes[0];
        assert_eq!((n0.node, n0.frames_sent, n0.bytes_sent), (0, 2, 200));
        let n1 = &c.nodes[1];
        assert_eq!(n1.frames_recvd, 1);
        assert_eq!(n1.cpu_busy, at(500));
        assert_eq!(n1.nic_busy, at(250));
        assert_eq!(n1.nic_stall, at(5));
        assert_eq!(n1.max_queue, 3);
        assert_eq!((n1.stores, n1.store_bytes), (1, 4096));
        assert_eq!(c.links.len(), 1);
        let l = &c.links[0];
        assert_eq!((l.src, l.dst, l.frames, l.bytes), (0, 1, 2, 200));
        // both frames were outstanding before the first delivery
        assert_eq!(l.max_in_flight, 200);
        assert!((c.cpu_util_pct(n1) - 50.0).abs() < 1e-9);
        assert!(!c.summary_lines().is_empty());
    }

    #[test]
    fn empty_trace_yields_empty_counters() {
        let c = derive_counters(&[]);
        assert_eq!(c.events, 0);
        assert_eq!(c.span, Duration::ZERO);
        assert!(c.nodes.is_empty() && c.links.is_empty());
    }
}
