//! Chrome-trace / Perfetto export: render a trace as a per-node Gantt
//! timeline loadable in `chrome://tracing` or <https://ui.perfetto.dev>.
//!
//! Layout: one process (`pid` 1), three tracks per node — *work* (fold and
//! gemm frame spans, store/failure instants), *cpu* (meter charges as
//! duration slices) and *nic* (reservation slices + frame send/recv
//! instants) — plus a *control* track (tid 0) for plan boundaries, repair
//! lifecycle, epochs, and per-node queue-depth counters. Timestamps are
//! virtual microseconds rendered with fixed sub-µs decimals from integer
//! nanoseconds (no float formatting), and all entries are sorted by
//! `(track, ts)`, so the output is deterministic and every track's `ts` is
//! monotonically non-decreasing with non-negative `dur`.

use std::collections::BTreeMap;

use super::{Event, EventKind};

const PID: u64 = 1;

/// Track id of cluster-scope events (plans, repairs, epochs, counters).
const CONTROL_TID: u64 = 0;

fn work_tid(node: usize) -> u64 {
    node as u64 * 3 + 1
}

fn cpu_tid(node: usize) -> u64 {
    node as u64 * 3 + 2
}

fn nic_tid(node: usize) -> u64 {
    node as u64 * 3 + 3
}

/// Integer-exact µs rendering of a nanosecond tick (three decimals).
fn us(ns: u128) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

struct Entry {
    tid: u64,
    ts_ns: u128,
    json: String,
}

fn complete(tid: u64, ts_ns: u128, dur_ns: u128, name: &str, args: &str) -> Entry {
    Entry {
        tid,
        ts_ns,
        json: format!(
            "{{\"ph\":\"X\",\"pid\":{PID},\"tid\":{tid},\"ts\":{},\"dur\":{},\"name\":\"{name}\",\"args\":{{{args}}}}}",
            us(ts_ns),
            us(dur_ns),
        ),
    }
}

fn instant(tid: u64, ts_ns: u128, name: &str, args: &str) -> Entry {
    Entry {
        tid,
        ts_ns,
        json: format!(
            "{{\"ph\":\"i\",\"pid\":{PID},\"tid\":{tid},\"ts\":{},\"s\":\"t\",\"name\":\"{name}\",\"args\":{{{args}}}}}",
            us(ts_ns),
        ),
    }
}

fn counter(ts_ns: u128, name: &str, key: &str, value: u128) -> Entry {
    Entry {
        tid: CONTROL_TID,
        ts_ns,
        json: format!(
            "{{\"ph\":\"C\",\"pid\":{PID},\"tid\":{CONTROL_TID},\"ts\":{},\"name\":\"{name}\",\"args\":{{\"{key}\":{value}}}}}",
            us(ts_ns),
        ),
    }
}

/// Render `events` (any order works; canonical sink order is the usual
/// input) as a complete Chrome trace-event JSON document.
pub fn chrome_trace(events: &[Event]) -> String {
    let mut entries: Vec<Entry> = Vec::with_capacity(events.len());
    // open fold spans: (node, frame, object, index) -> start ns
    let mut folds: BTreeMap<(usize, usize, Option<u64>, Option<usize>), u128> = BTreeMap::new();
    // open gemm spans: (node, frame, rows) -> start ns
    let mut gemms: BTreeMap<(usize, usize, usize), u128> = BTreeMap::new();
    let mut nodes_seen: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();

    for e in events {
        let ts = e.at.as_nanos();
        if let Some(n) = e.node {
            nodes_seen.insert(n);
        }
        match (&e.kind, e.node) {
            (
                EventKind::FrameSent {
                    dst,
                    bytes,
                    deliver_at,
                },
                Some(n),
            ) => {
                entries.push(instant(
                    nic_tid(n),
                    ts,
                    &format!("send->{dst}"),
                    &format!("\"bytes\":{bytes},\"deliver_us\":{}", us(deliver_at.as_nanos())),
                ));
            }
            (EventKind::FrameRecvd { src, bytes }, Some(n)) => {
                entries.push(instant(
                    nic_tid(n),
                    ts,
                    &format!("recv<-{src}"),
                    &format!("\"bytes\":{bytes}"),
                ));
            }
            (
                EventKind::NicStall {
                    dir,
                    stall,
                    busy,
                    bytes,
                },
                Some(n),
            ) => {
                entries.push(complete(
                    nic_tid(n),
                    ts,
                    (*stall + *busy).as_nanos(),
                    &format!("nic:{}", dir.label()),
                    &format!(
                        "\"bytes\":{bytes},\"stall_us\":{},\"busy_us\":{}",
                        us(stall.as_nanos()),
                        us(busy.as_nanos())
                    ),
                ));
            }
            (EventKind::CpuCharge { work, cost }, Some(n)) => {
                entries.push(complete(
                    cpu_tid(n),
                    ts,
                    cost.as_nanos(),
                    "cpu",
                    &format!(
                        "\"mac\":{},\"xor\":{},\"store\":{},\"inv\":{}",
                        work.mac_bytes, work.xor_bytes, work.store_bytes, work.invert_elems
                    ),
                ));
            }
            (
                EventKind::FoldStart {
                    object,
                    index,
                    frame,
                },
                Some(n),
            ) => {
                folds.insert((n, *frame, *object, *index), ts);
            }
            (
                EventKind::FoldEnd {
                    object,
                    index,
                    frame,
                },
                Some(n),
            ) => {
                if let Some(start) = folds.remove(&(n, *frame, *object, *index)) {
                    let args = match (object, index) {
                        (Some(o), Some(i)) => {
                            format!("\"object\":{o},\"index\":{i},\"frame\":{frame}")
                        }
                        _ => format!("\"frame\":{frame}"),
                    };
                    entries.push(complete(
                        work_tid(n),
                        start,
                        ts.saturating_sub(start),
                        "fold",
                        &args,
                    ));
                }
            }
            (EventKind::GemmStart { rows, frame }, Some(n)) => {
                gemms.insert((n, *frame, *rows), ts);
            }
            (EventKind::GemmEnd { rows, frame }, Some(n)) => {
                if let Some(start) = gemms.remove(&(n, *frame, *rows)) {
                    entries.push(complete(
                        work_tid(n),
                        start,
                        ts.saturating_sub(start),
                        "gemm",
                        &format!("\"rows\":{rows},\"frame\":{frame}"),
                    ));
                }
            }
            (
                EventKind::StoreDone {
                    object,
                    index,
                    bytes,
                },
                Some(n),
            ) => {
                entries.push(instant(
                    work_tid(n),
                    ts,
                    "store",
                    &format!("\"object\":{object},\"index\":{index},\"bytes\":{bytes}"),
                ));
            }
            (EventKind::QueueDepth { depth }, Some(n)) => {
                entries.push(counter(ts, &format!("queue:node{n}"), "depth", *depth as u128));
            }
            (EventKind::NodeFailed, Some(n)) => {
                entries.push(instant(work_tid(n), ts, "crash", ""));
            }
            (EventKind::NodeRevived, Some(n)) => {
                entries.push(instant(work_tid(n), ts, "revive", ""));
            }
            (EventKind::RepairTriggered { object, position }, _) => {
                entries.push(instant(
                    CONTROL_TID,
                    ts,
                    "repair-triggered",
                    &format!("\"object\":{object},\"position\":{position}"),
                ));
            }
            (
                EventKind::RepairCommitted {
                    object,
                    position,
                    newcomer,
                },
                _,
            ) => {
                entries.push(instant(
                    CONTROL_TID,
                    ts,
                    "repair-committed",
                    &format!("\"object\":{object},\"position\":{position},\"newcomer\":{newcomer}"),
                ));
            }
            (EventKind::PlanStart { object, nodes }, _) => {
                entries.push(instant(
                    CONTROL_TID,
                    ts,
                    "plan-start",
                    &format!("\"object\":{object},\"slots\":{}", nodes.len()),
                ));
            }
            (EventKind::PlanEnd { object, makespan }, _) => {
                entries.push(instant(
                    CONTROL_TID,
                    ts,
                    "plan-end",
                    &format!("\"object\":{object},\"makespan_us\":{}", us(makespan.as_nanos())),
                ));
            }
            (EventKind::Epoch {
                epoch,
                repaired,
                missing,
            }, _) => {
                entries.push(instant(
                    CONTROL_TID,
                    ts,
                    "epoch",
                    &format!("\"epoch\":{epoch},\"repaired\":{repaired},\"missing\":{missing}"),
                ));
            }
            // node-scoped variants without a node id (shouldn't happen):
            // dropped rather than invent a track
            _ => {}
        }
    }

    // per-track monotonic ts by construction
    entries.sort_by(|a, b| (a.tid, a.ts_ns, &a.json).cmp(&(b.tid, b.ts_ns, &b.json)));

    let mut out = String::with_capacity(entries.len() * 128 + 1024);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    let mut push = |line: String, out: &mut String| {
        if !first {
            out.push(',');
        }
        first = false;
        out.push('\n');
        out.push_str(&line);
    };
    let meta = |tid: u64, name: &str| {
        format!(
            "{{\"ph\":\"M\",\"pid\":{PID},\"tid\":{tid},\"name\":\"thread_name\",\"args\":{{\"name\":\"{name}\"}}}}"
        )
    };
    push(
        format!(
            "{{\"ph\":\"M\",\"pid\":{PID},\"name\":\"process_name\",\"args\":{{\"name\":\"rapidraid sim\"}}}}"
        ),
        &mut out,
    );
    push(meta(CONTROL_TID, "control"), &mut out);
    for &n in &nodes_seen {
        push(meta(work_tid(n), &format!("node {n} work")), &mut out);
        push(meta(cpu_tid(n), &format!("node {n} cpu")), &mut out);
        push(meta(nic_tid(n), &format!("node {n} nic")), &mut out);
    }
    for e in &entries {
        push(e.json.clone(), &mut out);
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::json::{parse_json, JsonValue};
    use crate::resources::GfWork;
    use crate::trace::Direction;
    use std::time::Duration;

    fn at(ns: u64) -> Duration {
        Duration::from_nanos(ns)
    }

    fn sample_events() -> Vec<Event> {
        vec![
            Event {
                at: at(1000),
                node: Some(0),
                kind: EventKind::FoldStart {
                    object: Some(3),
                    index: Some(1),
                    frame: 0,
                },
            },
            Event {
                at: at(1500),
                node: Some(0),
                kind: EventKind::CpuCharge {
                    work: GfWork::mac(64),
                    cost: at(400),
                },
            },
            Event {
                at: at(2000),
                node: Some(0),
                kind: EventKind::FoldEnd {
                    object: Some(3),
                    index: Some(1),
                    frame: 0,
                },
            },
            Event {
                at: at(2100),
                node: Some(0),
                kind: EventKind::NicStall {
                    dir: Direction::Up,
                    stall: at(10),
                    busy: at(90),
                    bytes: 128,
                },
            },
            Event {
                at: at(2200),
                node: Some(0),
                kind: EventKind::FrameSent {
                    dst: 1,
                    bytes: 128,
                    deliver_at: at(2500),
                },
            },
            Event {
                at: at(2500),
                node: Some(1),
                kind: EventKind::FrameRecvd { src: 0, bytes: 128 },
            },
            Event {
                at: at(2600),
                node: Some(1),
                kind: EventKind::QueueDepth { depth: 2 },
            },
            Event {
                at: at(3000),
                node: Some(0),
                kind: EventKind::PlanEnd {
                    object: 3,
                    makespan: at(2000),
                },
            },
        ]
    }

    #[test]
    fn export_is_valid_json_with_monotonic_tracks() {
        let doc = chrome_trace(&sample_events());
        let v = parse_json(&doc).unwrap();
        let evs = v
            .get("traceEvents")
            .and_then(JsonValue::as_arr)
            .expect("traceEvents array");
        assert!(evs.len() >= 8);
        let mut last_ts: std::collections::BTreeMap<(u64, u64), f64> = Default::default();
        for e in evs {
            let ph = e.get("ph").and_then(JsonValue::as_str).unwrap();
            if ph == "M" {
                continue;
            }
            let pid = e.get("pid").and_then(JsonValue::as_u64).unwrap();
            let tid = e.get("tid").and_then(JsonValue::as_u64).unwrap();
            let ts = e.get("ts").and_then(JsonValue::as_f64).unwrap();
            let prev = last_ts.insert((pid, tid), ts).unwrap_or(f64::MIN);
            assert!(ts >= prev, "track ({pid},{tid}) went backwards: {prev} -> {ts}");
            if ph == "X" {
                assert!(e.get("dur").and_then(JsonValue::as_f64).unwrap() >= 0.0);
            }
        }
        // fold span got stitched from start/end with its identity attached
        assert!(doc.contains("\"name\":\"fold\""), "{doc}");
        assert!(doc.contains("\"object\":3"));
        // queue gauge became a counter
        assert!(doc.contains("\"ph\":\"C\""));
    }

    #[test]
    fn fractional_us_rendering_is_integer_exact() {
        assert_eq!(us(0), "0.000");
        assert_eq!(us(999), "0.999");
        assert_eq!(us(1_234_567), "1234.567");
    }

    #[test]
    fn empty_trace_still_exports_a_document() {
        let doc = chrome_trace(&[]);
        let v = parse_json(&doc).unwrap();
        assert!(v.get("traceEvents").and_then(JsonValue::as_arr).is_some());
    }
}
