//! Critical-path attribution: walk the event stream of a finished archival
//! (or repair) plan and attribute its makespan to *compute* vs *transfer*
//! vs *upstream wait*, per chain/tree slot.
//!
//! The algorithm is a per-slot partition of the plan window. The executor
//! brackets every plan with `PlanStart { object, nodes }` /
//! `PlanEnd { object, makespan }` events; for each slot (a node bound to a
//! plan step) the window `[start, end]` decomposes as:
//!
//! * **compute** — the sum of the slot's `CpuCharge` costs inside the
//!   window (virtual time its CPU meter was genuinely reserved);
//! * **transfer** — the sum of its `NicStall` stall + wire-occupancy time
//!   (queueing behind earlier reservations plus serialization at the NIC
//!   rate), clamped so compute + transfer never exceeds the makespan
//!   (overlap is attributed to the earlier category in this order);
//! * **wait** — the remainder: time the slot sat blocked on upstream
//!   frames (or on plan-level skew).
//!
//! By construction the three parts of every slot sum *exactly* to the
//! plan's makespan — `trace-report` always accounts for 100% of where the
//! time went, and the slot with the least wait is the critical one (it
//! paced everyone else). Concurrent plans are disambiguated by object id
//! (starts and ends match LIFO per object).

use std::time::Duration;

use crate::clock::Tick;
use crate::cluster::NodeId;

use super::{Event, EventKind};

/// One plan slot's share of the makespan.
#[derive(Clone, Debug, PartialEq)]
pub struct SlotAttribution {
    /// The node bound to this slot.
    pub node: NodeId,
    /// CPU-meter time charged inside the plan window.
    pub compute: Tick,
    /// NIC stall + wire-occupancy time inside the window (clamped).
    pub transfer: Tick,
    /// Remainder: blocked on upstream frames / plan skew.
    pub wait: Tick,
}

impl SlotAttribution {
    /// Always equals the plan's makespan (the partition is exact).
    pub fn total(&self) -> Tick {
        self.compute + self.transfer + self.wait
    }
}

/// Attribution of one executed plan.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanAttribution {
    /// Object the plan operated on.
    pub object: u64,
    /// Virtual start of the plan window.
    pub start: Tick,
    /// Virtual end of the plan window.
    pub end: Tick,
    /// Per-slot partitions, in plan step order.
    pub slots: Vec<SlotAttribution>,
}

impl PlanAttribution {
    /// The plan's start→finish duration.
    pub fn makespan(&self) -> Tick {
        self.end.saturating_sub(self.start)
    }
}

/// Walk `events` (any order-preserving trace, e.g. a `JsonlSink`'s
/// canonical stream) and attribute every completed plan found in it.
pub fn attribute_plans(events: &[Event]) -> Vec<PlanAttribution> {
    // Open windows per object, LIFO (concurrent plans share a trace but
    // objects are distinct within a batch).
    let mut open: Vec<(u64, Tick, Vec<NodeId>)> = Vec::new();
    let mut done: Vec<PlanAttribution> = Vec::new();
    for e in events {
        match &e.kind {
            EventKind::PlanStart { object, nodes } => {
                open.push((*object, e.at, nodes.clone()));
            }
            EventKind::PlanEnd { object, .. } => {
                let Some(i) = open.iter().rposition(|(o, _, _)| o == object) else {
                    continue; // truncated trace: end without start
                };
                let (object, start, nodes) = open.remove(i);
                done.push(attribute_window(events, object, start, e.at, &nodes));
            }
            _ => {}
        }
    }
    done
}

fn attribute_window(
    events: &[Event],
    object: u64,
    start: Tick,
    end: Tick,
    nodes: &[NodeId],
) -> PlanAttribution {
    let makespan = end.saturating_sub(start);
    let slots = nodes
        .iter()
        .map(|&node| {
            let mut compute = Duration::ZERO;
            let mut transfer = Duration::ZERO;
            for e in events {
                if e.node != Some(node) || e.at < start || e.at > end {
                    continue;
                }
                match &e.kind {
                    EventKind::CpuCharge { cost, .. } => compute += *cost,
                    EventKind::NicStall { stall, busy, .. } => transfer += *stall + *busy,
                    _ => {}
                }
            }
            // Exact partition: overlapping or over-attributed categories
            // are clamped in (compute, transfer) order; wait absorbs the
            // rest.
            let compute = compute.min(makespan);
            let transfer = transfer.min(makespan.saturating_sub(compute));
            let wait = makespan.saturating_sub(compute + transfer);
            SlotAttribution {
                node,
                compute,
                transfer,
                wait,
            }
        })
        .collect();
    PlanAttribution {
        object,
        start,
        end,
        slots,
    }
}

/// Render attributions as the `trace-report` breakdown table.
pub fn render_attribution(plans: &[PlanAttribution]) -> String {
    let mut out = String::new();
    if plans.is_empty() {
        out.push_str("no completed plans in trace\n");
        return out;
    }
    for p in plans {
        let ms = p.makespan();
        out.push_str(&format!(
            "plan object={} makespan={:?} ({} slots)\n",
            p.object,
            ms,
            p.slots.len()
        ));
        for s in &p.slots {
            out.push_str(&format!(
                "  slot node={:>3}  compute {:>12?} ({:>5.1}%)  transfer {:>12?} ({:>5.1}%)  wait {:>12?} ({:>5.1}%)\n",
                s.node,
                s.compute,
                share(s.compute, ms),
                s.transfer,
                share(s.transfer, ms),
                s.wait,
                share(s.wait, ms),
            ));
        }
    }
    out
}

fn share(part: Tick, whole: Tick) -> f64 {
    if whole.is_zero() {
        return 0.0;
    }
    100.0 * part.as_secs_f64() / whole.as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::GfWork;
    use crate::trace::Direction;

    fn at(ns: u64) -> Tick {
        Duration::from_nanos(ns)
    }

    fn charge(node: NodeId, t: u64, cost: u64) -> Event {
        Event {
            at: at(t),
            node: Some(node),
            kind: EventKind::CpuCharge {
                work: GfWork::mac(1),
                cost: at(cost),
            },
        }
    }

    fn stall(node: NodeId, t: u64, stall_ns: u64, busy_ns: u64) -> Event {
        Event {
            at: at(t),
            node: Some(node),
            kind: EventKind::NicStall {
                dir: Direction::Up,
                stall: at(stall_ns),
                busy: at(busy_ns),
                bytes: 64,
            },
        }
    }

    #[test]
    fn partition_sums_exactly_to_makespan() {
        let events = vec![
            Event {
                at: at(100),
                node: Some(0),
                kind: EventKind::PlanStart {
                    object: 5,
                    nodes: vec![0, 1, 2],
                },
            },
            charge(0, 150, 200),
            stall(0, 200, 50, 100),
            charge(1, 300, 400),
            // node 2: no charges at all — pure wait
            // outside the window: ignored
            charge(1, 5000, 123),
            Event {
                at: at(1100),
                node: Some(0),
                kind: EventKind::PlanEnd {
                    object: 5,
                    makespan: at(1000),
                },
            },
        ];
        let plans = attribute_plans(&events);
        assert_eq!(plans.len(), 1);
        let p = &plans[0];
        assert_eq!(p.makespan(), at(1000));
        assert_eq!(p.slots.len(), 3);
        for s in &p.slots {
            assert_eq!(s.total(), p.makespan(), "slot {} partition leaks", s.node);
        }
        assert_eq!(p.slots[0].compute, at(200));
        assert_eq!(p.slots[0].transfer, at(150));
        assert_eq!(p.slots[0].wait, at(650));
        assert_eq!(p.slots[1].compute, at(400));
        assert_eq!(p.slots[2].compute, Duration::ZERO);
        assert_eq!(p.slots[2].wait, at(1000));
        let table = render_attribution(&plans);
        assert!(table.contains("object=5"), "{table}");
        assert!(table.contains("slot node=  2"), "{table}");
    }

    #[test]
    fn over_attribution_clamps_instead_of_overflowing() {
        let events = vec![
            Event {
                at: at(0),
                node: Some(0),
                kind: EventKind::PlanStart {
                    object: 1,
                    nodes: vec![0],
                },
            },
            charge(0, 10, 900),
            charge(0, 20, 900), // 1800 > 1000 makespan
            stall(0, 30, 500, 500),
            Event {
                at: at(1000),
                node: Some(0),
                kind: EventKind::PlanEnd {
                    object: 1,
                    makespan: at(1000),
                },
            },
        ];
        let p = &attribute_plans(&events)[0];
        let s = &p.slots[0];
        assert_eq!(s.compute, at(1000));
        assert_eq!(s.transfer, Duration::ZERO);
        assert_eq!(s.wait, Duration::ZERO);
        assert_eq!(s.total(), p.makespan());
    }

    #[test]
    fn unmatched_end_is_skipped_and_lifo_matches_objects() {
        let events = vec![
            Event {
                at: at(0),
                node: Some(0),
                kind: EventKind::PlanEnd {
                    object: 9,
                    makespan: at(1),
                },
            },
            Event {
                at: at(10),
                node: Some(0),
                kind: EventKind::PlanStart {
                    object: 1,
                    nodes: vec![0],
                },
            },
            Event {
                at: at(10),
                node: Some(1),
                kind: EventKind::PlanStart {
                    object: 2,
                    nodes: vec![1],
                },
            },
            Event {
                at: at(30),
                node: Some(1),
                kind: EventKind::PlanEnd {
                    object: 2,
                    makespan: at(20),
                },
            },
            Event {
                at: at(50),
                node: Some(0),
                kind: EventKind::PlanEnd {
                    object: 1,
                    makespan: at(40),
                },
            },
        ];
        let plans = attribute_plans(&events);
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].object, 2);
        assert_eq!(plans[0].makespan(), at(20));
        assert_eq!(plans[1].object, 1);
        assert_eq!(plans[1].makespan(), at(40));
        assert!(render_attribution(&[]).contains("no completed plans"));
    }
}
