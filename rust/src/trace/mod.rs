//! Deterministic dataplane tracing: a typed event bus for the whole
//! simulator.
//!
//! Every instrumentation point in the dataplane funnels through one macro,
//! [`trace_emit!`]: a typed [`Event`] stamped with the *cluster clock's*
//! virtual time, the emitting node, and a payload variant
//! ([`EventKind`]) — frames on the wire, NIC stalls, CPU charges, fold and
//! gemm spans, store landings, queue-depth gauges, failure and repair
//! lifecycle, plan boundaries and workload epochs. Events flow into any
//! number of installed [`TraceSink`]s (a ring buffer, a JSONL writer, and —
//! via [`perfetto`] — a Chrome-trace exporter rendering a run as a per-node
//! Gantt timeline); [`counters`] and [`critical`] derive per-node/per-link
//! counters and critical-path attribution from the raw stream.
//!
//! ## Determinism contract
//!
//! * **No sink installed ⇒ zero observable effect.** The emit macro
//!   compiles to a branch on a process-wide `OnceLock` registry (plus a
//!   relaxed active-session counter): until the first install the event
//!   expression is never even evaluated, no clock is read, and the
//!   dataplane stays byte- and tick-identical to an untraced build —
//!   `tests/determinism.rs` guards exactly this.
//! * **Sinks observe, never perturb.** Recording takes no clock sleeps and
//!   registers no participants, so virtual time cannot advance (or stall)
//!   because of tracing; a traced SimClock run takes the same ticks as an
//!   untraced one.
//! * **Byte-identical traces per seed.** Under a `SimClock` the *multiset*
//!   of events per tick is deterministic, but OS thread scheduling may
//!   interleave same-tick emits differently across runs. [`sink::JsonlSink`]
//!   therefore canonicalizes at the end: lines are sorted by
//!   `(tick, serialized line)`, making the output a pure function of the
//!   event multiset — same seed ⇒ byte-identical JSONL.
//! * **Isolation.** A session installed with [`install`] only receives
//!   events stamped by *that clock* (filtered by `Arc` pointer identity), so
//!   concurrently running tests with their own clusters cannot pollute each
//!   other's traces. [`install_global`] (the CLI path, one scenario per
//!   process) receives everything.

pub mod counters;
pub mod critical;
pub mod perfetto;
pub mod reader;
pub mod sink;

pub use counters::{derive_counters, LinkCounters, NodeCounters, TraceCounters};
pub use critical::{attribute_plans, render_attribution, PlanAttribution, SlotAttribution};
pub use perfetto::chrome_trace;
pub use reader::{parse_event, parse_jsonl};
pub use sink::{canonical_order, to_canonical_jsonl, JsonlSink, RingSink};

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use crate::clock::{ClockHandle, Tick};
use crate::cluster::NodeId;
use crate::resources::GfWork;

/// Which side of a link a NIC reservation was made on.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Direction {
    /// The sender's uplink (acquired, paces the sending worker).
    Up,
    /// The receiver's downlink (reserved, shifts the delivery instant).
    Down,
}

impl Direction {
    /// Stable wire label.
    pub fn label(&self) -> &'static str {
        match self {
            Direction::Up => "up",
            Direction::Down => "down",
        }
    }
}

/// One typed observation from the dataplane.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    /// Virtual time on the emitting cluster's clock.
    pub at: Tick,
    /// Emitting node, when the event has one (`None` = cluster-scope:
    /// plan boundaries, workload epochs).
    pub node: Option<NodeId>,
    /// What happened.
    pub kind: EventKind,
}

/// Payload variants of a trace [`Event`].
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// A frame left `node` for `dst` (stamped at send, after NIC pacing).
    FrameSent {
        /// Receiving node.
        dst: NodeId,
        /// Wire bytes of the frame.
        bytes: usize,
        /// Virtual instant the frame arrives at `dst`.
        deliver_at: Tick,
    },
    /// A frame from `src` was consumed by `node`'s receiving worker.
    FrameRecvd {
        /// Sending node.
        src: NodeId,
        /// Wire bytes of the frame.
        bytes: usize,
    },
    /// A NIC token-bucket reservation: how long the caller queued behind
    /// earlier reservations (`stall`) and how long the wire itself is
    /// occupied (`busy`).
    NicStall {
        /// Uplink (sender) or downlink (receiver) reservation.
        dir: Direction,
        /// Queueing delay behind earlier reservations.
        stall: Tick,
        /// Serialization time of these bytes at the NIC rate.
        busy: Tick,
        /// Reserved bytes.
        bytes: usize,
    },
    /// A data-plane worker charged GF compute on its node's `CpuMeter`.
    CpuCharge {
        /// The work units priced.
        work: GfWork,
        /// Virtual compute time charged.
        cost: Tick,
    },
    /// A fold (pipeline stage) started processing one frame.
    FoldStart {
        /// Object of the stored output, when this stage stores one.
        object: Option<u64>,
        /// Codeword index of the stored output, when known.
        index: Option<usize>,
        /// Frame sequence number within the stream.
        frame: usize,
    },
    /// The matching end of a [`EventKind::FoldStart`] (same frame).
    FoldEnd {
        /// Object of the stored output, when this stage stores one.
        object: Option<u64>,
        /// Codeword index of the stored output, when known.
        index: Option<usize>,
        /// Frame sequence number within the stream.
        frame: usize,
    },
    /// A gemm step started one frame's row sweep.
    GemmStart {
        /// Parity rows computed per frame.
        rows: usize,
        /// Frame sequence number within the stream.
        frame: usize,
    },
    /// The matching end of a [`EventKind::GemmStart`] (same frame).
    GemmEnd {
        /// Parity rows computed per frame.
        rows: usize,
        /// Frame sequence number within the stream.
        frame: usize,
    },
    /// A block landed in a node's store.
    StoreDone {
        /// Owning object.
        object: u64,
        /// Block index within the object.
        index: usize,
        /// Stored bytes.
        bytes: usize,
    },
    /// A node's command-queue depth changed (gauge: queued + active).
    QueueDepth {
        /// Commands queued or running after the change.
        depth: usize,
    },
    /// The node was crash-stopped.
    NodeFailed,
    /// The node came back (empty).
    NodeRevived,
    /// The scheduler planned a repair of one lost block.
    RepairTriggered {
        /// Object being repaired.
        object: u64,
        /// Codeword position of the lost block.
        position: usize,
    },
    /// A planned repair executed and its chain rebind committed.
    RepairCommitted {
        /// Object that was repaired.
        object: u64,
        /// Codeword position of the regenerated block.
        position: usize,
        /// Node now holding the block.
        newcomer: NodeId,
    },
    /// A plan began executing (stamped by the executor before dispatch).
    PlanStart {
        /// Object the plan operates on.
        object: u64,
        /// Nodes bound to the plan's steps, in step order (the slots the
        /// critical-path analyzer attributes over).
        nodes: Vec<NodeId>,
    },
    /// The matching end of a [`EventKind::PlanStart`].
    PlanEnd {
        /// Object the plan operated on.
        object: u64,
        /// Virtual start→finish duration of the plan.
        makespan: Tick,
    },
    /// One workload epoch's summary (the long-run harness's `EpochStats`).
    Epoch {
        /// Epoch index.
        epoch: u64,
        /// Blocks repaired by this epoch's scheduler pass.
        repaired: usize,
        /// Coded blocks still missing after the pass.
        missing: usize,
    },
}

impl EventKind {
    /// Stable wire name of the variant.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::FrameSent { .. } => "frame_sent",
            EventKind::FrameRecvd { .. } => "frame_recvd",
            EventKind::NicStall { .. } => "nic_stall",
            EventKind::CpuCharge { .. } => "cpu_charge",
            EventKind::FoldStart { .. } => "fold_start",
            EventKind::FoldEnd { .. } => "fold_end",
            EventKind::GemmStart { .. } => "gemm_start",
            EventKind::GemmEnd { .. } => "gemm_end",
            EventKind::StoreDone { .. } => "store_done",
            EventKind::QueueDepth { .. } => "queue_depth",
            EventKind::NodeFailed => "node_failed",
            EventKind::NodeRevived => "node_revived",
            EventKind::RepairTriggered { .. } => "repair_triggered",
            EventKind::RepairCommitted { .. } => "repair_committed",
            EventKind::PlanStart { .. } => "plan_start",
            EventKind::PlanEnd { .. } => "plan_end",
            EventKind::Epoch { .. } => "epoch",
        }
    }
}

impl Event {
    /// The canonical one-line JSON form ([`reader::parse_event`] is its
    /// inverse). Field order is fixed, so the line doubles as the
    /// deterministic sort tie-break for same-tick events.
    pub fn to_json_line(&self) -> String {
        let mut s = String::with_capacity(96);
        s.push_str("{\"t\":");
        push_u128(&mut s, self.at.as_nanos());
        if let Some(n) = self.node {
            s.push_str(",\"node\":");
            push_u128(&mut s, n as u128);
        }
        s.push_str(",\"ev\":\"");
        s.push_str(self.kind.name());
        s.push('"');
        match &self.kind {
            EventKind::FrameSent {
                dst,
                bytes,
                deliver_at,
            } => {
                field(&mut s, "dst", *dst as u128);
                field(&mut s, "bytes", *bytes as u128);
                field(&mut s, "deliver", deliver_at.as_nanos());
            }
            EventKind::FrameRecvd { src, bytes } => {
                field(&mut s, "src", *src as u128);
                field(&mut s, "bytes", *bytes as u128);
            }
            EventKind::NicStall {
                dir,
                stall,
                busy,
                bytes,
            } => {
                s.push_str(",\"dir\":\"");
                s.push_str(dir.label());
                s.push('"');
                field(&mut s, "stall", stall.as_nanos());
                field(&mut s, "busy", busy.as_nanos());
                field(&mut s, "bytes", *bytes as u128);
            }
            EventKind::CpuCharge { work, cost } => {
                field(&mut s, "mac", work.mac_bytes as u128);
                field(&mut s, "xor", work.xor_bytes as u128);
                field(&mut s, "store", work.store_bytes as u128);
                field(&mut s, "inv", work.invert_elems as u128);
                field(&mut s, "cost", cost.as_nanos());
            }
            EventKind::FoldStart {
                object,
                index,
                frame,
            }
            | EventKind::FoldEnd {
                object,
                index,
                frame,
            } => {
                if let Some(o) = object {
                    field(&mut s, "object", *o as u128);
                }
                if let Some(i) = index {
                    field(&mut s, "index", *i as u128);
                }
                field(&mut s, "frame", *frame as u128);
            }
            EventKind::GemmStart { rows, frame } | EventKind::GemmEnd { rows, frame } => {
                field(&mut s, "rows", *rows as u128);
                field(&mut s, "frame", *frame as u128);
            }
            EventKind::StoreDone {
                object,
                index,
                bytes,
            } => {
                field(&mut s, "object", *object as u128);
                field(&mut s, "index", *index as u128);
                field(&mut s, "bytes", *bytes as u128);
            }
            EventKind::QueueDepth { depth } => {
                field(&mut s, "depth", *depth as u128);
            }
            EventKind::NodeFailed | EventKind::NodeRevived => {}
            EventKind::RepairTriggered { object, position } => {
                field(&mut s, "object", *object as u128);
                field(&mut s, "position", *position as u128);
            }
            EventKind::RepairCommitted {
                object,
                position,
                newcomer,
            } => {
                field(&mut s, "object", *object as u128);
                field(&mut s, "position", *position as u128);
                field(&mut s, "newcomer", *newcomer as u128);
            }
            EventKind::PlanStart { object, nodes } => {
                field(&mut s, "object", *object as u128);
                s.push_str(",\"nodes\":[");
                for (i, n) in nodes.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    push_u128(&mut s, *n as u128);
                }
                s.push(']');
            }
            EventKind::PlanEnd { object, makespan } => {
                field(&mut s, "object", *object as u128);
                field(&mut s, "makespan", makespan.as_nanos());
            }
            EventKind::Epoch {
                epoch,
                repaired,
                missing,
            } => {
                field(&mut s, "epoch", *epoch as u128);
                field(&mut s, "repaired", *repaired as u128);
                field(&mut s, "missing", *missing as u128);
            }
        }
        s.push('}');
        s
    }
}

fn push_u128(s: &mut String, v: u128) {
    s.push_str(&v.to_string());
}

fn field(s: &mut String, key: &str, v: u128) {
    s.push_str(",\"");
    s.push_str(key);
    s.push_str("\":");
    push_u128(s, v);
}

/// Where trace events go. Implementations must be cheap and non-blocking
/// on the simulation's critical path: no clock sleeps, no participant
/// registration, no I/O per event (buffer, flush at the end).
pub trait TraceSink: Send + Sync + std::fmt::Debug {
    /// Observe one event. Called from data-plane threads; may run
    /// concurrently.
    fn record(&self, event: &Event);
}

struct Session {
    id: u64,
    /// `Some(key)` = only events stamped by the clock with this identity;
    /// `None` = every clock in the process.
    clock: Option<usize>,
    sink: Arc<dyn TraceSink>,
}

struct Registry {
    sessions: RwLock<Vec<Session>>,
    next_id: AtomicU64,
    active: AtomicUsize,
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

fn registry() -> &'static Registry {
    REGISTRY.get_or_init(|| Registry {
        sessions: RwLock::new(Vec::new()),
        next_id: AtomicU64::new(1),
        active: AtomicUsize::new(0),
    })
}

/// Identity of a clock for session filtering: the `Arc`'s data pointer.
fn clock_key(clock: &ClockHandle) -> usize {
    Arc::as_ptr(clock) as *const u8 as usize
}

/// Fast path of [`trace_emit!`]: true iff at least one session is
/// installed. Until the first install this is a `OnceLock` miss — the
/// macro's event expression is never evaluated.
#[inline]
pub fn enabled() -> bool {
    match REGISTRY.get() {
        None => false,
        Some(r) => r.active.load(Ordering::Relaxed) != 0,
    }
}

/// Stamp `kind` with `clock.now()` and deliver it to every matching
/// session. Prefer [`trace_emit!`], which skips all of this when tracing
/// is off.
pub fn emit(clock: &ClockHandle, node: impl Into<Option<NodeId>>, kind: EventKind) {
    let at = clock.now();
    emit_at(clock, at, node, kind);
}

/// [`emit`] with an explicit timestamp (for events whose natural instant
/// precedes the emit point, e.g. a NIC stall stamped at request time).
pub fn emit_at(clock: &ClockHandle, at: Tick, node: impl Into<Option<NodeId>>, kind: EventKind) {
    let Some(reg) = REGISTRY.get() else { return };
    let key = clock_key(clock);
    let event = Event {
        at,
        node: node.into(),
        kind,
    };
    let sessions = reg.sessions.read().unwrap();
    for s in sessions.iter() {
        let matches = match s.clock {
            None => true,
            Some(c) => c == key,
        };
        if matches {
            s.sink.record(&event);
        }
    }
}

/// Uninstalls its session on drop.
#[must_use = "dropping the guard uninstalls the trace session"]
#[derive(Debug)]
pub struct TraceGuard {
    id: u64,
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        let reg = registry();
        let mut sessions = reg.sessions.write().unwrap();
        if let Some(i) = sessions.iter().position(|s| s.id == self.id) {
            sessions.remove(i);
            reg.active.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

fn install_inner(clock: Option<usize>, sink: Arc<dyn TraceSink>) -> TraceGuard {
    let reg = registry();
    let id = reg.next_id.fetch_add(1, Ordering::Relaxed);
    reg.sessions.write().unwrap().push(Session { id, clock, sink });
    reg.active.fetch_add(1, Ordering::Relaxed);
    TraceGuard { id }
}

/// Install `sink` for events stamped by `clock` only (the test-safe form:
/// concurrent clusters on other clocks stay invisible).
pub fn install(clock: &ClockHandle, sink: Arc<dyn TraceSink>) -> TraceGuard {
    install_inner(Some(clock_key(clock)), sink)
}

/// Install `sink` for every clock in the process (the CLI form — one
/// scenario per process, including scenarios that build a fresh `SimClock`
/// per cell).
pub fn install_global(sink: Arc<dyn TraceSink>) -> TraceGuard {
    install_inner(None, sink)
}

/// Emit a trace event if (and only if) tracing is on.
///
/// `$clock` is the emitting component's `ClockHandle`, `$node` anything
/// `Into<Option<NodeId>>` (a node id, or `None` for cluster-scope events),
/// `$kind` an [`EventKind`] expression — evaluated only when a sink is
/// installed, so an untraced run never pays for payload construction.
/// The `@at` form stamps an explicit tick instead of `clock.now()`.
#[macro_export]
macro_rules! trace_emit {
    (@at $at:expr, $clock:expr, $node:expr, $kind:expr) => {
        if $crate::trace::enabled() {
            $crate::trace::emit_at(&$clock, $at, $node, $kind);
        }
    };
    ($clock:expr, $node:expr, $kind:expr) => {
        if $crate::trace::enabled() {
            $crate::trace::emit(&$clock, $node, $kind);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::SimClock;
    use std::time::Duration;

    #[test]
    fn json_lines_are_stable_and_named() {
        let e = Event {
            at: Duration::from_nanos(1500),
            node: Some(3),
            kind: EventKind::FrameSent {
                dst: 4,
                bytes: 1024,
                deliver_at: Duration::from_nanos(2500),
            },
        };
        assert_eq!(
            e.to_json_line(),
            "{\"t\":1500,\"node\":3,\"ev\":\"frame_sent\",\"dst\":4,\"bytes\":1024,\"deliver\":2500}"
        );
        let e = Event {
            at: Duration::ZERO,
            node: None,
            kind: EventKind::Epoch {
                epoch: 7,
                repaired: 1,
                missing: 0,
            },
        };
        assert_eq!(
            e.to_json_line(),
            "{\"t\":0,\"ev\":\"epoch\",\"epoch\":7,\"repaired\":1,\"missing\":0}"
        );
    }

    #[test]
    fn sessions_filter_by_clock_identity() {
        let a: ClockHandle = SimClock::handle();
        let b: ClockHandle = SimClock::handle();
        let sink = JsonlSink::shared();
        let _guard = install(&a, sink.clone());
        assert!(enabled());
        emit(&a, 0, EventKind::NodeFailed);
        emit(&b, 1, EventKind::NodeFailed); // other clock: filtered out
        let events = sink.events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].node, Some(0));
    }

    #[test]
    fn guard_drop_uninstalls() {
        let clock: ClockHandle = SimClock::handle();
        let sink = JsonlSink::shared();
        {
            let _guard = install(&clock, sink.clone());
            emit(&clock, 0, EventKind::NodeRevived);
        }
        emit(&clock, 0, EventKind::NodeRevived); // after drop: not recorded
        assert_eq!(sink.events().len(), 1);
    }

    #[test]
    fn global_session_sees_every_clock() {
        let a: ClockHandle = SimClock::handle();
        let b: ClockHandle = SimClock::handle();
        let sink = JsonlSink::shared();
        let _guard = install_global(sink.clone());
        // marker payload: concurrently running traced tests are also
        // visible to a global session, so count only our own events
        let marker = |pos| EventKind::RepairTriggered {
            object: 0xdead_beef,
            position: pos,
        };
        emit(&a, 0, marker(1));
        emit(&b, 1, marker(2));
        let ours: Vec<_> = sink
            .events()
            .into_iter()
            .filter(|e| matches!(e.kind, EventKind::RepairTriggered { object, .. } if object == 0xdead_beef))
            .collect();
        assert_eq!(ours.len(), 2, "global session must see both clocks");
    }
}
