//! The built-in [`TraceSink`] implementations.
//!
//! * [`RingSink`] — a bounded, lock-free-enough ring: an atomic cursor
//!   claims slots, each slot is its own tiny mutex, so concurrent
//!   data-plane threads never contend on one global lock and the newest
//!   `capacity` events are always available (live inspection, the
//!   adaptive-control-plane feed).
//! * [`JsonlSink`] — collects every event and canonicalizes at the end:
//!   lines sorted by `(tick, line)`, making the serialized trace a pure
//!   function of the event *multiset* — the byte-identical-per-seed
//!   guarantee `tests/determinism.rs` asserts.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::{Event, TraceSink};

/// Sort `events` into canonical order: by `(tick, serialized line)`. The
/// result is a pure function of the event *multiset* — the order both
/// sinks serialize in, independent of the OS interleaving that recorded
/// same-tick events.
pub fn canonical_order(events: Vec<Event>) -> Vec<Event> {
    let mut keyed: Vec<(String, Event)> = events
        .into_iter()
        .map(|e| (e.to_json_line(), e))
        .collect();
    keyed.sort_by(|a, b| a.1.at.cmp(&b.1.at).then_with(|| a.0.cmp(&b.0)));
    keyed.into_iter().map(|(_, e)| e).collect()
}

/// Serialize `events` as the canonical JSONL document (one event per line,
/// trailing newline; empty string for no events). Sorts internally — the
/// input order does not matter.
pub fn to_canonical_jsonl(events: Vec<Event>) -> String {
    let events = canonical_order(events);
    let mut out = String::new();
    for e in &events {
        out.push_str(&e.to_json_line());
        out.push('\n');
    }
    out
}

/// Bounded in-memory ring keeping the newest `capacity` events.
#[derive(Debug)]
pub struct RingSink {
    slots: Vec<Mutex<Option<Event>>>,
    cursor: AtomicUsize,
}

impl RingSink {
    /// A ring holding the newest `capacity` events (`capacity` ≥ 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "ring capacity must be positive");
        Self {
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
            cursor: AtomicUsize::new(0),
        }
    }

    /// [`RingSink::new`] as a shareable handle.
    pub fn shared(capacity: usize) -> Arc<Self> {
        Arc::new(Self::new(capacity))
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn recorded(&self) -> usize {
        self.cursor.load(Ordering::Relaxed)
    }

    /// The retained events, oldest first. Taken while recording may still
    /// be in flight, the snapshot is a best-effort view (slots claimed but
    /// not yet written are skipped); quiescent, it is exact.
    pub fn snapshot(&self) -> Vec<Event> {
        let total = self.recorded();
        let cap = self.slots.len();
        let (start, len) = if total <= cap {
            (0, total)
        } else {
            (total % cap, cap)
        };
        let mut out = Vec::with_capacity(len);
        for i in 0..len {
            let slot = &self.slots[(start + i) % cap];
            if let Some(e) = slot.lock().unwrap().clone() {
                out.push(e);
            }
        }
        out
    }

    /// True iff events were overwritten: more than `capacity` recorded, so
    /// [`RingSink::snapshot`] no longer holds the full multiset.
    pub fn overflowed(&self) -> bool {
        self.recorded() > self.slots.len()
    }

    /// The retained events serialized as canonical JSONL (sorted by
    /// `(tick, line)` — byte-identical to a [`JsonlSink`] of the same
    /// multiset whenever the ring did not overflow).
    pub fn to_jsonl(&self) -> String {
        to_canonical_jsonl(self.snapshot())
    }

    /// Write the retained events as canonical JSONL to `path`.
    pub fn write_jsonl(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_jsonl())
            .map_err(|e| anyhow::anyhow!("writing trace {}: {e}", path.display()))
    }
}

impl TraceSink for RingSink {
    fn record(&self, event: &Event) {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed) % self.slots.len();
        *self.slots[i].lock().unwrap() = Some(event.clone());
    }
}

/// Collects every event; serializes to canonical, deterministic JSONL.
#[derive(Debug, Default)]
pub struct JsonlSink {
    events: Mutex<Vec<Event>>,
}

impl JsonlSink {
    /// An empty collector.
    pub fn new() -> Self {
        Self::default()
    }

    /// [`JsonlSink::new`] as a shareable handle.
    pub fn shared() -> Arc<Self> {
        Arc::new(Self::new())
    }

    /// Recorded event count.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    /// True iff nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The events in canonical order: sorted by `(tick, serialized line)`.
    /// This is the order [`JsonlSink::to_jsonl`] writes, independent of the
    /// OS interleaving that produced same-tick events.
    pub fn events(&self) -> Vec<Event> {
        canonical_order(self.events.lock().unwrap().clone())
    }

    /// The canonical JSONL document (one event per line, trailing newline;
    /// empty string when no events were recorded).
    pub fn to_jsonl(&self) -> String {
        to_canonical_jsonl(self.events.lock().unwrap().clone())
    }

    /// Write the canonical JSONL document to `path`.
    pub fn write_jsonl(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_jsonl())
            .map_err(|e| anyhow::anyhow!("writing trace {}: {e}", path.display()))
    }
}

impl TraceSink for JsonlSink {
    fn record(&self, event: &Event) {
        self.events.lock().unwrap().push(event.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::EventKind;
    use std::time::Duration;

    fn ev(ns: u64, node: usize, depth: usize) -> Event {
        Event {
            at: Duration::from_nanos(ns),
            node: Some(node),
            kind: EventKind::QueueDepth { depth },
        }
    }

    #[test]
    fn ring_keeps_newest_capacity_events() {
        let ring = RingSink::new(3);
        for i in 0..5 {
            ring.record(&ev(i, 0, i as usize));
        }
        assert_eq!(ring.recorded(), 5);
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(
            snap.iter().map(|e| e.at.as_nanos()).collect::<Vec<_>>(),
            vec![2, 3, 4]
        );
    }

    #[test]
    fn ring_partial_fill_snapshots_in_order() {
        let ring = RingSink::new(8);
        ring.record(&ev(5, 1, 0));
        ring.record(&ev(7, 2, 0));
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].at, Duration::from_nanos(5));
    }

    #[test]
    fn jsonl_output_is_sorted_and_canonical() {
        let a = JsonlSink::new();
        let b = JsonlSink::new();
        // same multiset, opposite insertion order (two ticks + a same-tick
        // pair whose lines differ)
        let events = [ev(20, 1, 0), ev(10, 0, 0), ev(10, 2, 3)];
        for e in &events {
            a.record(e);
        }
        for e in events.iter().rev() {
            b.record(e);
        }
        assert_eq!(a.to_jsonl(), b.to_jsonl());
        let doc = a.to_jsonl();
        let lines: Vec<&str> = doc.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("\"t\":10"));
        assert!(lines[1].contains("\"t\":10"));
        assert!(lines[2].contains("\"t\":20"));
        // same-tick tie broken by line text, deterministically
        assert!(lines[0] < lines[1]);
    }

    #[test]
    fn ring_jsonl_matches_unbounded_sink_until_overflow() {
        let ring = RingSink::new(8);
        let full = JsonlSink::new();
        let events = [ev(20, 1, 0), ev(10, 0, 0), ev(10, 2, 3), ev(15, 1, 1)];
        for e in &events {
            ring.record(e);
            full.record(e);
        }
        assert!(!ring.overflowed());
        assert_eq!(ring.to_jsonl(), full.to_jsonl());
        // overflow: oldest events drop, flag flips
        for i in 0..8 {
            ring.record(&ev(30 + i, 3, 0));
        }
        assert!(ring.overflowed());
        assert_eq!(ring.snapshot().len(), 8);
    }

    #[test]
    fn empty_jsonl_is_empty_string() {
        let s = JsonlSink::new();
        assert!(s.is_empty());
        assert_eq!(s.to_jsonl(), "");
    }
}
