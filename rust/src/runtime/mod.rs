//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (`make artifacts`) and executes them from the Rust hot path.
//!
//! Interchange is HLO **text** (`artifacts/*.hlo.txt` + `manifest.txt`):
//! the image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos
//! (64-bit instruction ids), while the text parser reassigns ids — see
//! /opt/xla-example/README.md. Each artifact is compiled once on the PJRT
//! CPU client and cached; Python never runs at request time.

pub mod artifacts;

// The real executor drives the PJRT CPU client through the `xla` bindings
// crate; that dependency is not available in the offline build, so it sits
// behind the `pjrt` cargo feature. The default build substitutes an
// uninhabited stub whose `load` explains how to enable the real path —
// every caller already handles `load` errors (artifacts may be absent), so
// the two builds are behaviorally identical until artifacts + xla exist.
#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(not(feature = "pjrt"))]
#[path = "executor_stub.rs"]
pub mod executor;

pub use artifacts::{ArtifactKind, ArtifactMeta, Manifest};
pub use executor::PjrtEngine;
