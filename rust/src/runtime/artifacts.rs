//! Artifact manifest parsing (`artifacts/manifest.txt`).
//!
//! One artifact per line, space-separated `key=value` pairs:
//!
//! ```text
//! name=gf8_gemm_m5_k11 kind=gemm w=8 m=5 k=11 r=0 b=65536 file=gf8_gemm_m5_k11.hlo.txt
//! name=gf8_step_r1   kind=step w=8 m=0 k=0  r=1 b=65536 file=gf8_step_r1.hlo.txt
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::backend::Width;

/// What computation an artifact implements.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum ArtifactKind {
    /// `parity[m,b] = gmat[m,k] ⊗ data[k,b]`.
    Gemm,
    /// `(x_out[b], c[b]) = step(x[b], locals[r,b], psi[r], xi[r])`.
    Step,
}

/// Metadata of one AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Unique artifact name.
    pub name: String,
    /// Computation kind.
    pub kind: ArtifactKind,
    /// Field width.
    pub width: Width,
    /// Gemm output rows (0 for step).
    pub m: usize,
    /// Gemm input rows (0 for step).
    pub k: usize,
    /// Step local-block arity (0 for gemm).
    pub r: usize,
    /// Payload length in field SYMBOLS (b bytes for w=8, 2b bytes for w=16).
    pub b: usize,
    /// HLO text file path (absolute).
    pub path: PathBuf,
}

impl ArtifactMeta {
    /// Payload length in BYTES.
    pub fn buf_bytes(&self) -> usize {
        self.b * self.width.symbol_bytes()
    }
}

/// Parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.txt")).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {}/manifest.txt (run `make artifacts` first): {e}",
                dir.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text, resolving file paths against `dir`.
    pub fn parse(text: &str, dir: &Path) -> anyhow::Result<Self> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let kv: HashMap<&str, &str> = line
                .split_whitespace()
                .filter_map(|tok| tok.split_once('='))
                .collect();
            let get = |key: &str| -> anyhow::Result<&str> {
                kv.get(key)
                    .copied()
                    .ok_or_else(|| anyhow::anyhow!("manifest line {}: missing {key}", lineno + 1))
            };
            let kind = match get("kind")? {
                "gemm" => ArtifactKind::Gemm,
                "step" => ArtifactKind::Step,
                other => anyhow::bail!("manifest line {}: unknown kind {other}", lineno + 1),
            };
            let width = match get("w")? {
                "8" => Width::W8,
                "16" => Width::W16,
                other => anyhow::bail!("manifest line {}: unknown width {other}", lineno + 1),
            };
            entries.push(ArtifactMeta {
                name: get("name")?.to_string(),
                kind,
                width,
                m: get("m")?.parse()?,
                k: get("k")?.parse()?,
                r: get("r")?.parse()?,
                b: get("b")?.parse()?,
                path: dir.join(get("file")?),
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest is empty");
        Ok(Self { entries })
    }

    /// All artifacts.
    pub fn entries(&self) -> &[ArtifactMeta] {
        &self.entries
    }

    /// Smallest gemm artifact fitting an (m, k) request at `width`
    /// (rows/cols are zero-padded by the executor when strictly larger).
    pub fn find_gemm(&self, width: Width, m: usize, k: usize) -> Option<&ArtifactMeta> {
        self.entries
            .iter()
            .filter(|a| a.kind == ArtifactKind::Gemm && a.width == width && a.m >= m && a.k >= k)
            .min_by_key(|a| (a.m, a.k))
    }

    /// Step artifact with exactly arity `r` at `width`.
    pub fn find_step(&self, width: Width, r: usize) -> Option<&ArtifactMeta> {
        self.entries
            .iter()
            .find(|a| a.kind == ArtifactKind::Step && a.width == width && a.r == r)
    }
}

/// Default artifacts directory: `$RAPIDRAID_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("RAPIDRAID_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
name=gf8_gemm_m5_k11 kind=gemm w=8 m=5 k=11 r=0 b=65536 file=a.hlo.txt
name=gf16_gemm_m5_k11 kind=gemm w=16 m=5 k=11 r=0 b=32768 file=b.hlo.txt
name=gf8_gemm_m11_k11 kind=gemm w=8 m=11 k=11 r=0 b=65536 file=c.hlo.txt
name=gf8_step_r1 kind=step w=8 m=0 k=0 r=1 b=65536 file=d.hlo.txt

# comment line
name=gf8_step_r2 kind=step w=8 m=0 k=0 r=2 b=65536 file=e.hlo.txt
";

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert_eq!(m.entries().len(), 5);
        let g = m.find_gemm(Width::W8, 5, 11).unwrap();
        assert_eq!(g.name, "gf8_gemm_m5_k11");
        assert_eq!(g.path, Path::new("/x/a.hlo.txt"));
        assert_eq!(g.buf_bytes(), 65536);
        // (4,4) request fits the 5x11 artifact (smaller than 11x11)
        let g2 = m.find_gemm(Width::W8, 4, 4).unwrap();
        assert_eq!(g2.name, "gf8_gemm_m5_k11");
        // 11 rows needs the big one
        let g3 = m.find_gemm(Width::W8, 11, 11).unwrap();
        assert_eq!(g3.name, "gf8_gemm_m11_k11");
        // no w16 step in this manifest
        assert!(m.find_step(Width::W16, 1).is_none());
        assert_eq!(m.find_step(Width::W8, 2).unwrap().name, "gf8_step_r2");
        // w16 buf bytes: 32768 symbols * 2
        assert_eq!(m.find_gemm(Width::W16, 1, 1).unwrap().buf_bytes(), 65536);
    }

    #[test]
    fn oversize_request_unmatched() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert!(m.find_gemm(Width::W8, 12, 11).is_none());
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(Manifest::parse("name=x kind=nope w=8 m=0 k=0 r=0 b=1 file=f", Path::new("/")).is_err());
        assert!(Manifest::parse("", Path::new("/")).is_err());
        assert!(Manifest::parse("kind=gemm w=8 m=0 k=0 r=0 b=1 file=f", Path::new("/")).is_err());
    }
}
