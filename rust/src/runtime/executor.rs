//! PJRT executor: compile-once / execute-many wrappers over the `xla`
//! crate, typed for the two artifact kinds.
//!
//! All GF payloads travel as raw bytes; shapes are zero-padded up to the
//! artifact's fixed AOT shape (GF-linear maps send zero to zero, so
//! padding never changes the meaningful prefix of the result) and the
//! outputs are truncated back.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::Context;

use super::artifacts::{ArtifactMeta, Manifest};
use crate::backend::Width;

fn prim(width: Width) -> xla::ElementType {
    match width {
        Width::W8 => xla::ElementType::U8,
        Width::W16 => xla::ElementType::U16,
    }
}

/// Extract a literal's payload as little-endian bytes, honoring its width.
fn literal_bytes(lit: &xla::Literal, width: Width) -> anyhow::Result<Vec<u8>> {
    match width {
        Width::W8 => Ok(lit.to_vec::<u8>()?),
        Width::W16 => {
            let words = lit.to_vec::<u16>()?;
            let mut out = Vec::with_capacity(words.len() * 2);
            for w in words {
                out.extend_from_slice(&w.to_le_bytes());
            }
            Ok(out)
        }
    }
}

/// Compile-once, execute-many PJRT engine over an artifact directory.
///
/// Interior mutability (`Mutex`) because the underlying PJRT handles are
/// not `Sync`; callers share the engine behind `Arc<PjrtEngine>`.
pub struct PjrtEngine {
    inner: Mutex<Inner>,
    manifest: Manifest,
}

struct Inner {
    client: xla::PjRtClient,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

// SAFETY: all access to the PJRT client and executables is serialized
// through the Mutex; the raw pointers inside are never shared unlocked.
unsafe impl Send for PjrtEngine {}
unsafe impl Sync for PjrtEngine {}

impl PjrtEngine {
    /// Create a CPU PJRT client and load the artifact manifest from `dir`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self {
            inner: Mutex::new(Inner {
                client,
                compiled: HashMap::new(),
            }),
            manifest,
        })
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Names of artifacts compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.inner.lock().unwrap().compiled.len()
    }

    /// Execute a gemm artifact: `out[m][..] = Σ_j mat[m][j] ⊗ data[j]`.
    ///
    /// `data` blocks may be up to the artifact buffer size; shorter blocks
    /// (and an (m, k) smaller than the artifact's) are zero-padded, outputs
    /// truncated to the input length.
    pub fn gemm(
        &self,
        width: Width,
        mat: &[Vec<u32>],
        data: &[&[u8]],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        let m = mat.len();
        let k = data.len();
        anyhow::ensure!(m > 0 && k > 0, "empty gemm");
        anyhow::ensure!(mat.iter().all(|r| r.len() == k), "matrix/data shape mismatch");
        let len = data[0].len();
        anyhow::ensure!(data.iter().all(|d| d.len() == len), "ragged data blocks");
        let meta = self
            .manifest
            .find_gemm(width, m, k)
            .ok_or_else(|| anyhow::anyhow!("no gemm artifact fits ({width}, m={m}, k={k})"))?
            .clone();
        // Blocks larger than the artifact's fixed panel are processed in
        // panel-sized chunks (the kernels are elementwise across the B
        // axis, so chunking is exact).
        if len > meta.buf_bytes() {
            let mut out: Vec<Vec<u8>> = vec![Vec::with_capacity(len); m];
            let mut offset = 0;
            while offset < len {
                let chunk = meta.buf_bytes().min(len - offset);
                let data_chunk: Vec<&[u8]> =
                    data.iter().map(|d| &d[offset..offset + chunk]).collect();
                let part = self.gemm(width, mat, &data_chunk)?;
                for (o, p) in out.iter_mut().zip(part) {
                    o.extend_from_slice(&p);
                }
                offset += chunk;
            }
            return Ok(out);
        }

        // gmat literal: (am, ak), embedded top-left.
        let (am, ak) = (meta.m, meta.k);
        let sym = width.symbol_bytes();
        let mut gmat = vec![0u8; am * ak * sym];
        for (i, row) in mat.iter().enumerate() {
            for (j, &c) in row.iter().enumerate() {
                let off = (i * ak + j) * sym;
                match width {
                    Width::W8 => gmat[off] = c as u8,
                    Width::W16 => gmat[off..off + 2].copy_from_slice(&(c as u16).to_le_bytes()),
                }
            }
        }
        // data literal: (ak, b) bytes, rows zero-padded.
        let row_bytes = meta.buf_bytes();
        let mut panel = vec![0u8; ak * row_bytes];
        for (j, d) in data.iter().enumerate() {
            panel[j * row_bytes..j * row_bytes + d.len()].copy_from_slice(d);
        }

        let lit_g = xla::Literal::create_from_shape_and_untyped_data(
            prim(width),
            &[am, ak],
            &gmat,
        )?;
        let lit_d = xla::Literal::create_from_shape_and_untyped_data(
            prim(width),
            &[ak, meta.b],
            &panel,
        )?;
        let outputs = self.execute(&meta, &[lit_g, lit_d], 1, width)?;
        let full = &outputs[0];
        // outputs[0] is (am, b); keep the first m rows truncated to len.
        let mut out = Vec::with_capacity(m);
        for i in 0..m {
            out.push(full[i * row_bytes..i * row_bytes + len].to_vec());
        }
        Ok(out)
    }

    /// Execute a step artifact: `(x_out, c)` for one pipeline stage.
    pub fn pipeline_step(
        &self,
        width: Width,
        x_in: &[u8],
        locals: &[&[u8]],
        psi: &[u32],
        xi: &[u32],
    ) -> anyhow::Result<(Vec<u8>, Vec<u8>)> {
        let r = locals.len();
        anyhow::ensure!(r > 0, "pipeline step with no locals");
        anyhow::ensure!(psi.len() == r && xi.len() == r, "coefficient arity mismatch");
        let len = x_in.len();
        anyhow::ensure!(locals.iter().all(|l| l.len() == len), "length mismatch");
        let meta = self
            .manifest
            .find_step(width, r)
            .ok_or_else(|| anyhow::anyhow!("no step artifact for ({width}, r={r})"))?
            .clone();
        anyhow::ensure!(
            len <= meta.buf_bytes(),
            "buffer of {len} B exceeds artifact buffer {} B",
            meta.buf_bytes()
        );

        let sym = width.symbol_bytes();
        let row_bytes = meta.buf_bytes();
        let mut x_pad = vec![0u8; row_bytes];
        x_pad[..len].copy_from_slice(x_in);
        let mut loc_panel = vec![0u8; r * row_bytes];
        for (j, l) in locals.iter().enumerate() {
            loc_panel[j * row_bytes..j * row_bytes + len].copy_from_slice(l);
        }
        let coef_bytes = |cs: &[u32]| -> Vec<u8> {
            let mut out = vec![0u8; r * sym];
            for (j, &c) in cs.iter().enumerate() {
                match width {
                    Width::W8 => out[j] = c as u8,
                    Width::W16 => out[j * 2..j * 2 + 2].copy_from_slice(&(c as u16).to_le_bytes()),
                }
            }
            out
        };

        let lit_x =
            xla::Literal::create_from_shape_and_untyped_data(prim(width), &[meta.b], &x_pad)?;
        let lit_l = xla::Literal::create_from_shape_and_untyped_data(
            prim(width),
            &[r, meta.b],
            &loc_panel,
        )?;
        let lit_p =
            xla::Literal::create_from_shape_and_untyped_data(prim(width), &[r], &coef_bytes(psi))?;
        let lit_q =
            xla::Literal::create_from_shape_and_untyped_data(prim(width), &[r], &coef_bytes(xi))?;
        let outputs = self.execute(&meta, &[lit_x, lit_l, lit_p, lit_q], 2, width)?;
        Ok((outputs[0][..len].to_vec(), outputs[1][..len].to_vec()))
    }

    /// Compile (cached) and execute one artifact; returns the raw bytes of
    /// each tuple element.
    fn execute(
        &self,
        meta: &ArtifactMeta,
        args: &[xla::Literal],
        expect_outputs: usize,
        width: Width,
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.compiled.contains_key(&meta.name) {
            let proto = xla::HloModuleProto::from_text_file(
                meta.path
                    .to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
            )
            .with_context(|| format!("parse HLO text {}", meta.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .with_context(|| format!("PJRT compile {}", meta.name))?;
            inner.compiled.insert(meta.name.clone(), exe);
        }
        let exe = inner.compiled.get(&meta.name).unwrap();
        let result = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("execute {}", meta.name))?[0][0]
            .to_literal_sync()?;
        // Artifacts are lowered with return_tuple=True.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == expect_outputs,
            "{} returned {} outputs, expected {expect_outputs}",
            meta.name,
            parts.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(literal_bytes(&p, width)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    //! Execution against real artifacts lives in rust/tests/pjrt_runtime.rs
    //! (needs `make artifacts` to have run). Here: pure plumbing tests.
    use super::*;

    #[test]
    fn missing_dir_is_reported() {
        let err = match PjrtEngine::load(Path::new("/nonexistent-dir")) {
            Err(e) => e,
            Ok(_) => panic!("load of missing dir must fail"),
        };
        assert!(err.to_string().contains("manifest"), "{err}");
    }

    #[test]
    fn prim_mapping() {
        assert!(matches!(prim(Width::W8), xla::ElementType::U8));
        assert!(matches!(prim(Width::W16), xla::ElementType::U16));
    }
}
