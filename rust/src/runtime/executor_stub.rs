//! Stub PJRT executor compiled when the `pjrt` cargo feature is off.
//!
//! The real executor (`executor.rs`) needs the `xla` bindings crate, which
//! the offline build environment does not ship. This stub keeps the whole
//! crate compiling with identical public signatures: [`PjrtEngine`] is an
//! uninhabited type, so every method body after a failed `load` is
//! statically unreachable and the compiler verifies no codepath can use it.

use std::path::Path;

use super::artifacts::Manifest;
use crate::backend::Width;

/// Uninhabited stand-in for the PJRT engine (enable the `pjrt` feature and
/// add the `xla` dependency to get the real one).
pub enum PjrtEngine {}

impl PjrtEngine {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn load(_dir: &Path) -> anyhow::Result<Self> {
        anyhow::bail!(
            "PJRT runtime unavailable: this build has no `pjrt` feature; \
             rebuild with `cargo build --features pjrt` after adding the \
             `xla` bindings dependency"
        )
    }

    /// The loaded manifest (unreachable: `Self` is uninhabited).
    pub fn manifest(&self) -> &Manifest {
        match *self {}
    }

    /// Names of artifacts compiled so far (unreachable).
    pub fn compiled_count(&self) -> usize {
        match *self {}
    }

    /// Execute a gemm artifact (unreachable).
    pub fn gemm(
        &self,
        _width: Width,
        _mat: &[Vec<u32>],
        _data: &[&[u8]],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        match *self {}
    }

    /// Execute a step artifact (unreachable).
    pub fn pipeline_step(
        &self,
        _width: Width,
        _x_in: &[u8],
        _locals: &[&[u8]],
        _psi: &[u32],
        _xi: &[u32],
    ) -> anyhow::Result<(Vec<u8>, Vec<u8>)> {
        match *self {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = PjrtEngine::load(Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
