//! End-to-end driver (DESIGN.md §6): the full archival system on a real
//! small workload — recorded in EXPERIMENTS.md.
//!
//! 16-node cluster (EC2 preset), 16 objects of 11 × 1 MiB (the paper's
//! (16,11) layout at 1/64 block scale), each 2-way replicated. We:
//!
//!  1. batch-archive all 16 objects with classical CEC and measure,
//!  2. batch-archive all 16 objects with RapidRAID RR8 and measure,
//!  3. archive a single idle-cluster object with both (Fig. 4a point),
//!  4. migrate every RR object for real (verify decode → drop replicas),
//!  5. verify every object decodes bit-exactly after node failures.
//!
//! ```sh
//! cargo run --release --example archive_cluster            # native backend
//! cargo run --release --example archive_cluster -- --pjrt  # AOT kernels
//! ```

use std::sync::Arc;
use std::time::Duration;

use rapidraid::backend::{BackendHandle, NativeBackend, PjrtBackend};
use rapidraid::bench_scenarios::{build_jobs, rr8_code, Impl, BUF_BYTES, K, N};
use rapidraid::cluster::{Cluster, ClusterSpec};
use rapidraid::coordinator::batch::{rotated_chain, run_batch};
use rapidraid::coordinator::{ingest_object, migrate_object, reconstruct};
use rapidraid::metrics::Recorder;
use rapidraid::runtime::artifacts::default_dir;
use rapidraid::storage::{BlockKey, ObjectId, ReplicaPlacement};

const BLOCK: usize = 1 << 20; // 1 MiB blocks (paper: 64 MiB; ratios preserved)
const OBJECTS: usize = 16;

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let backend: BackendHandle = if use_pjrt {
        println!("backend: pjrt ({})", default_dir().display());
        Arc::new(PjrtBackend::load(&default_dir())?)
    } else {
        println!("backend: native");
        Arc::new(NativeBackend::new())
    };
    println!(
        "== archive_cluster: {} objects x {} x {} MiB on {} EC2-preset nodes ==",
        OBJECTS,
        K,
        BLOCK >> 20,
        N
    );
    let rec = Recorder::new();

    // --- 1+2: concurrent batch archival, CEC vs RR8 -----------------------
    for imp in [Impl::Cec, Impl::Rr8] {
        let cluster = Cluster::start(ClusterSpec::ec2(N));
        let jobs = build_jobs(&cluster, imp, OBJECTS, BLOCK, 0)?;
        let times = run_batch(&cluster, &backend, &jobs)?;
        for t in &times {
            rec.record(&format!("batch16/{imp}"), *t);
        }
        let total: Duration = *times.iter().max().unwrap();
        println!(
            "{imp}: batch of {OBJECTS} archived; slowest object {total:?}, per-object median {:?}",
            rec.candle(&format!("batch16/{imp}")).unwrap().median()
        );
    }

    // --- 3: single object on an idle cluster (Fig. 4a point) --------------
    for imp in [Impl::Cec, Impl::Rr8] {
        let cluster = Cluster::start(ClusterSpec::ec2(N));
        let jobs = build_jobs(&cluster, imp, 1, BLOCK, 500)?;
        let times = run_batch(&cluster, &backend, &jobs)?;
        rec.record(&format!("single/{imp}"), times[0]);
        println!("{imp}: single idle-cluster object archived in {:?}", times[0]);
    }
    let cec = rec.candle("single/CEC").unwrap().median().as_secs_f64();
    let rr8 = rec.candle("single/RR8").unwrap().median().as_secs_f64();
    println!(
        ">>> single-object coding-time reduction RR8 vs CEC: {:.1}% (paper: up to 90%)",
        100.0 * (1.0 - rr8 / cec)
    );
    let bc = rec.candle("batch16/CEC").unwrap().median().as_secs_f64();
    let br = rec.candle("batch16/RR8").unwrap().median().as_secs_f64();
    println!(
        ">>> 16-object per-object reduction RR8 vs CEC: {:.1}% (paper: up to 20% on EC2)",
        100.0 * (1.0 - br / bc)
    );

    // --- 4: real migration (encode -> verify -> drop replicas) ------------
    let cluster = Cluster::start(ClusterSpec::ec2(N));
    let code = rr8_code();
    let mut stored = Vec::new();
    for i in 0..OBJECTS as u64 {
        let object = ObjectId(9000 + i);
        let placement = ReplicaPlacement::new(object, K, rotated_chain(N, N, i as usize))?;
        let blocks = ingest_object(&cluster, &placement, BLOCK)?;
        stored.push((placement, blocks));
    }
    let mut reclaimed = 0usize;
    for (placement, blocks) in &stored {
        let report = migrate_object(&cluster, &code, placement, blocks, &backend, BUF_BYTES)?;
        reclaimed += report.replicas_dropped;
        rec.record("migrate/RR8", report.coding_time);
    }
    println!(
        "migrated {} objects: {} replica blocks reclaimed; storage 2.00x -> {:.2}x",
        stored.len(),
        reclaimed,
        N as f64 / K as f64
    );

    // --- 5: failure + decode verification ----------------------------------
    let mut verified = 0;
    for (i, (placement, blocks)) in stored.iter().enumerate() {
        // lose a sliding window of n-k = 5 coded blocks
        for f in 0..(N - K) {
            let pos = (i + f) % N;
            cluster
                .node(placement.chain[pos])
                .delete(BlockKey::coded(placement.object, pos))?;
        }
        let rec_blocks = reconstruct(&cluster, &code, &placement.chain, placement.object, &backend)?;
        anyhow::ensure!(&rec_blocks == blocks, "decode mismatch for {}", placement.object);
        verified += 1;
    }
    println!("{verified}/{} objects decode bit-exactly after losing n-k=5 blocks each", stored.len());

    println!("\n== summary ==\n{}", rec.markdown());
    println!("archive_cluster OK");
    Ok(())
}
