//! Fig. 5 scenario, end-to-end with narrative output: coding times as the
//! netem congestion profile (500 Mbps + 100±10 ms) spreads across nodes.
//!
//! The paper's observation to reproduce: a SINGLE congested node already
//! wrecks classical coding times (everything funnels through the coding
//! node, so any slow participant stalls the whole object), while RapidRAID
//! degrades quasi-linearly (a congested node only lengthens its own stage).
//!
//! ```sh
//! cargo run --release --example congested_archival [-- --pjrt]
//! ```

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend, PjrtBackend};
use rapidraid::bench_scenarios::{build_jobs, Impl, N};
use rapidraid::cluster::{Cluster, ClusterSpec, CongestionSpec};
use rapidraid::coordinator::batch::run_batch;
use rapidraid::runtime::artifacts::default_dir;

const BLOCK: usize = 1 << 20;

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let backend: BackendHandle = if use_pjrt {
        println!("backend: pjrt ({})", default_dir().display());
        Arc::new(PjrtBackend::load(&default_dir())?)
    } else {
        println!("backend: native");
        Arc::new(NativeBackend::new())
    };
    println!("== congested archival: (16,11), TPC preset, netem = 500 Mbps + 100±10 ms ==\n");
    println!(
        "{:>10} {:>12} {:>12} {:>9}",
        "congested", "CEC", "RR8", "CEC/RR8"
    );

    let profile = CongestionSpec::paper_netem();
    let mut base: Option<(f64, f64)> = None;
    for congested in [0usize, 1, 2, 4, 8] {
        let mut secs = Vec::new();
        for imp in [Impl::Cec, Impl::Rr8] {
            let cluster = Cluster::start(ClusterSpec::tpc(N));
            for node in 0..congested {
                cluster.congest(node, &profile);
            }
            let jobs = build_jobs(&cluster, imp, 1, BLOCK, 77_000 + congested as u64 * 10)?;
            let times = run_batch(&cluster, &backend, &jobs)?;
            secs.push(times[0].as_secs_f64());
        }
        println!(
            "{:>10} {:>11.3}s {:>11.3}s {:>8.1}x",
            congested,
            secs[0],
            secs[1],
            secs[0] / secs[1]
        );
        if congested == 0 {
            base = Some((secs[0], secs[1]));
        } else if congested == 1 {
            let (b_cec, b_rr) = base.unwrap();
            println!(
                "           -> one congested node inflates CEC {:.1}x but RR8 only {:.1}x",
                secs[0] / b_cec,
                secs[1] / b_rr
            );
        }
    }
    println!("\ncongested_archival OK (compare with paper Fig. 5a)");
    Ok(())
}
