//! Quickstart: the smallest full RapidRAID loop.
//!
//! Spins up an 8-node simulated cluster, stores one 4-block object with two
//! replicas (the paper's Fig. 2 layout), archives it with the (8,4)
//! pipelined code, kills half the coded blocks, and decodes the object back
//! bit-exactly.
//!
//! ```sh
//! cargo run --release --example quickstart            # native GF backend
//! cargo run --release --example quickstart -- --pjrt  # AOT Pallas kernels
//! ```

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend, PjrtBackend};
use rapidraid::cluster::{Cluster, ClusterSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::coordinator::{archive_pipeline, ingest_object, reconstruct, PipelineJob};
use rapidraid::gf::Gf65536;
use rapidraid::runtime::artifacts::default_dir;
use rapidraid::storage::{BlockKey, ObjectId, ReplicaPlacement};

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let backend: BackendHandle = if use_pjrt {
        println!("backend: pjrt ({})", default_dir().display());
        Arc::new(PjrtBackend::load(&default_dir())?)
    } else {
        println!("backend: native");
        Arc::new(NativeBackend::new())
    };

    // 1. an 8-node cluster on the ThinClient (1 GbE) preset
    let cluster = Cluster::start(ClusterSpec::tpc(8));

    // 2. one object of k=4 x 1 MiB, replicated twice across the 8 nodes
    let object = ObjectId(1);
    let placement = ReplicaPlacement::new(object, 4, (0..8).collect())?;
    let blocks = ingest_object(&cluster, &placement, 1 << 20)?;
    println!("ingested {object}: 4 x 1 MiB, 2 replicas over 8 nodes");

    // 3. archive with the paper's (8,4) RapidRAID code
    let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 12)?;
    let job = PipelineJob::from_code(&code, &placement, 65536, 1 << 20)?;
    let dt = archive_pipeline(&cluster, &backend, &job)?;
    println!("pipelined encode finished in {dt:?} (7 overlapped block hops)");

    // 4. disaster: lose 4 of the 8 coded blocks
    for pos in [0usize, 2, 5, 7] {
        cluster.node(pos).delete(BlockKey::coded(object, pos))?;
        println!("  node {pos} lost its coded block");
    }

    // 5. decode from the 4 survivors and verify
    let recovered = reconstruct(&cluster, &code, &placement.chain, object, &backend)?;
    assert_eq!(recovered, blocks, "decode mismatch!");
    println!("object recovered bit-exactly from 4 surviving blocks. quickstart OK");
    Ok(())
}
