//! Fig. 3 + Table I regeneration: dependency census and static resilience.
//!
//! ```sh
//! cargo run --release --example reliability_report
//! ```
//!
//! Checks the paper's analytical claims as it goes:
//!  * Fig. 3 / Conjecture 1 — (n,k) RapidRAID is MDS iff k ≥ n−3
//!    (n ∈ {8,12,16}, all n/2 ≤ k < n).
//!  * Section IV-B — the (8,4) code has exactly ONE natural dependency,
//!    {c1, c2, c5, c6}.
//!  * Table I — static resilience in 9's for p ∈ {0.2, 0.1, 0.01, 0.001}.

use rapidraid::codes::{census, rapidraid::RapidRaidCode};
use rapidraid::gf::Gf65536;
use rapidraid::reliability::table1;

fn main() -> anyhow::Result<()> {
    println!("== Fig. 3 — linear dependencies of (n,k) RapidRAID codewords ==");
    println!(
        "{:>4} {:>4} {:>10} {:>12} {:>14} {:>6}",
        "n", "k", "subsets", "dependent", "%independent", "MDS"
    );
    let mut conjecture_holds = true;
    for n in [8usize, 12, 16] {
        for k in (n / 2)..n {
            let r = census(n, k, 3, 1)?;
            let mds = r.is_mds();
            if mds != (k >= n - 3) {
                conjecture_holds = false;
            }
            println!(
                "{:>4} {:>4} {:>10} {:>12} {:>13.4}% {:>6}",
                n,
                k,
                r.total_subsets,
                r.dependent_count(),
                r.percent_independent(),
                if mds { "yes" } else { "no" }
            );
        }
    }
    println!(
        "Conjecture 1 (MDS iff k >= n-3): {}",
        if conjecture_holds { "HOLDS for all n <= 16" } else { "VIOLATED" }
    );
    anyhow::ensure!(conjecture_holds, "Conjecture 1 violated!");

    println!("\n== Section IV-B — the (8,4) natural dependency ==");
    let r84 = census(8, 4, 4, 2)?;
    println!(
        "(8,4): {} / {} subsets dependent: {:?} (paper: exactly {{c1,c2,c5,c6}})",
        r84.dependent_count(),
        r84.total_subsets,
        r84.natural_dependent
    );
    anyhow::ensure!(r84.natural_dependent == vec![vec![0, 1, 4, 5]]);

    println!("\n== Table I — static resiliency (number of 9's) ==");
    let code = RapidRaidCode::<Gf65536>::with_seed(16, 11, 12)?;
    println!(
        "{:<24} {:>7} {:>7} {:>7} {:>8}",
        "scheme", "p=0.2", "p=0.1", "p=0.01", "p=0.001"
    );
    for row in table1(16, 11, code.generator()) {
        print!("{:<24}", row.scheme);
        for v in row.nines {
            print!(" {v:>7}");
        }
        println!();
    }
    println!("\n(paper Table I: replication 2/3/6/9; classical EC 1/2/8/14; RapidRAID 0/2/6/11)");
    println!("reliability_report OK");
    Ok(())
}
