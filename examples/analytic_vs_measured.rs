//! Cross-check the simulator against the paper's analytic coding-time
//! models (eq. (1) and eq. (2)).
//!
//! For several (n, k) and block sizes on an idle TPC-preset cluster, run
//! both archival strategies and compare measured times with
//! `T_classical = τ_block · max{k, m−1}` and `T_pipe = τ_block + (n−1)·τ_pipe`.
//!
//! ```sh
//! cargo run --release --example analytic_vs_measured
//! ```

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend, Width};
use rapidraid::cluster::{Cluster, ClusterSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::codes::ClassicalCode;
use rapidraid::coordinator::batch::rotated_chain;
use rapidraid::coordinator::model::{t_classical, t_pipe, NetModel};
use rapidraid::coordinator::{
    archive_classical, archive_pipeline, ingest_object, ClassicalJob, PipelineJob,
};
use rapidraid::gf::{Gf256, GfElem};
use rapidraid::storage::{ObjectId, ReplicaPlacement};

const BUF: usize = 65536;

fn main() -> anyhow::Result<()> {
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    println!("== analytic (eq. 1 / eq. 2) vs measured, idle TPC cluster ==");
    println!(
        "{:>8} {:>10} {:>12} {:>12} {:>8} {:>12} {:>12} {:>8}",
        "(n,k)", "block", "eq1_cls", "meas_cls", "err", "eq2_pipe", "meas_pipe", "err"
    );

    for (n, k) in [(8usize, 4usize), (16, 11), (12, 8)] {
        for block_mib in [1usize, 4] {
            let block = block_mib << 20;
            let spec = ClusterSpec::tpc(n);
            let net = NetModel {
                bytes_per_sec: spec.bytes_per_sec,
                latency: spec.latency,
            };
            let predicted_cls = t_classical(&net, k, n - k, block);
            let predicted_pipe = t_pipe(&net, n, block, BUF);

            // measured classical
            let cluster = Cluster::start(spec.clone());
            let object = ObjectId(1);
            let chain = rotated_chain(n, n, 0);
            let placement = ReplicaPlacement::new(object, k, chain.clone())?;
            ingest_object(&cluster, &placement, block)?;
            let cls_code = ClassicalCode::<Gf256>::new(n, k)?;
            let parity = cls_code.parity_matrix();
            let job = ClassicalJob {
                object,
                width: Width::W8,
                parity_rows: (0..parity.rows())
                    .map(|i| parity.row(i).iter().map(|c| c.to_u32()).collect())
                    .collect(),
                source_nodes: chain[..k].to_vec(),
                coding_node: chain[k],
                parity_nodes: chain[k..].to_vec(),
                buf_bytes: BUF,
                block_bytes: block,
            };
            let meas_cls = archive_classical(&cluster, &backend, &job)?;

            // measured pipelined
            let cluster = Cluster::start(spec);
            let object = ObjectId(2);
            let placement = ReplicaPlacement::new(object, k, rotated_chain(n, n, 0))?;
            ingest_object(&cluster, &placement, block)?;
            let code = RapidRaidCode::<Gf256>::with_seed(n, k, 5)?;
            let pjob = PipelineJob::from_code(&code, &placement, BUF, block)?;
            let meas_pipe = archive_pipeline(&cluster, &backend, &pjob)?;

            let err = |pred: std::time::Duration, meas: std::time::Duration| {
                100.0 * (meas.as_secs_f64() - pred.as_secs_f64()) / pred.as_secs_f64()
            };
            println!(
                "{:>8} {:>8}MiB {:>12.3?} {:>12.3?} {:>+7.1}% {:>12.3?} {:>12.3?} {:>+7.1}%",
                format!("({n},{k})"),
                block_mib,
                predicted_cls,
                meas_cls,
                err(predicted_cls, meas_cls),
                predicted_pipe,
                meas_pipe,
                err(predicted_pipe, meas_pipe),
            );
        }
    }
    println!("\n(model ignores CPU time; positive error = simulator slower than ideal)");
    println!("analytic_vs_measured OK");
    Ok(())
}
